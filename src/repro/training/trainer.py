"""Training step construction: grad-accum, clipping, lr schedule, local-SGD.

``make_train_step`` builds the pjit-able pure function
    (params, opt_state, batch, step) -> (params, opt_state, metrics)
used by both the real trainer (launch/train.py) and the dry-run.

Distributed-optimization tricks (the knobs Hemingway's planner chooses
between, mirroring the paper's algorithm menu):
  * sync data-parallel AdamW/Adafactor (the baseline "mini-batch" algorithm)
  * local-SGD / DiLoCo-style H local steps + outer sync (CoCoA's
    communication-avoidance idea applied to LMs) — see local_sgd_outer
  * gradient compression (repro.compression) applied at the sync boundary
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.model import LM
from repro.training.optimizers import Optimizer, clip_by_global_norm


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    grad_clip: float = 1.0
    microbatches: int = 1     # gradient accumulation factor
    # local-SGD (H>1 => H local steps between outer syncs)
    local_steps: int = 1
    compression: Optional[str] = None  # None | "int8" | "topk" | "powersgd"


def rescaled_config(cfg: TrainConfig, batch_ratio: float,
                    local_steps: Optional[int] = None) -> TrainConfig:
    """Adjust a TrainConfig after an elastic resize: linear lr-scaling with
    the global-batch ratio (Goyal et al.), optionally switching the
    local-SGD sync period (the sync_relax mitigation).  Used by the chaos
    closed loop when a ResizeDecision changes the data-parallel degree."""
    return dataclasses.replace(
        cfg,
        learning_rate=cfg.learning_rate * batch_ratio,
        local_steps=cfg.local_steps if local_steps is None else
        max(int(local_steps), 1))


def lr_schedule(cfg: TrainConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    total = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    frac = jnp.clip((s - cfg.warmup_steps) / total, 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.learning_rate * jnp.where(s < cfg.warmup_steps, warm, decay)


def _split_microbatches(batch: Dict, n: int) -> Dict:
    """(B, ...) -> (n, B//n, ...) for every leaf."""
    return jax.tree.map(
        lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]), batch)


def make_train_step(lm: LM, opt: Optimizer, cfg: TrainConfig,
                    compressor=None) -> Callable:
    """Returns step(params, opt_state, batch, step_idx) -> (p, s, metrics)."""

    def loss_fn(params, batch):
        return lm.loss_fn(params, batch)

    def step_fn(params, opt_state, batch, step_idx):
        if cfg.microbatches > 1:
            micro = _split_microbatches(batch, cfg.microbatches)

            def accum(carry, mb):
                g_acc, l_acc = carry
                (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + loss), None

            zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                 params)
            (grads, loss_sum), _ = jax.lax.scan(
                accum, (zeros, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / cfg.microbatches, grads)
            loss = loss_sum / cfg.microbatches
            metrics_extra = {}
        else:
            (loss, metrics_extra), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        if compressor is not None:
            grads, opt_state = compressor.apply(grads, opt_state)
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        lr = lr_schedule(cfg, step_idx)
        new_params, new_opt = opt.update(grads, opt_state, params, lr)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        metrics.update({k: v for k, v in dict(metrics_extra).items()
                        if jnp.ndim(v) == 0})
        return new_params, new_opt, metrics

    return step_fn


# ---------------------------------------------------------------------------
# Local-SGD (communication-avoiding data parallelism) — CoCoA's idea applied
# to LM training: H inner steps per data shard with NO cross-shard gradient
# sync, then one parameter averaging.  Expressed as shard_map over the data
# axes: inside, the loss mean and optimizer run per shard (psum over 'model'
# only, inserted by GSPMD for the TP dims); the outer sync is a pmean of the
# params every H steps.  The dry-run lowers both variants to compare
# collective bytes (EXPERIMENTS.md §Perf).
# ---------------------------------------------------------------------------
def make_diloco_inner_step(lm: LM, opt: Optimizer, cfg: TrainConfig,
                           n_replicas: int):
    """DiLoCo-style inner step: vmap the whole train step over a leading
    replica axis.  Each replica holds its own (model-sharded) parameter copy
    which diverges between outer syncs; sharding the replica axis over
    'data' makes the inner step free of data-axis gradient collectives --
    the LM-training analogue of CoCoA's local SDCA rounds.  Outer sync
    (every H steps) is a mean of params over replicas, amortizing the
    gradient all-reduce by 1/H.  Param memory is x n_replicas vs FSDP (the
    trade Hemingway's planner weighs).
    """
    base = make_train_step(lm, opt, cfg)

    def inner(params_r, opt_state_r, batch_r, step_idx):
        # params_r: leading axis n_replicas (sharded over 'data'); batch_r:
        # (n_replicas, per_replica_batch, ...)
        return jax.vmap(lambda p, o, b: base(p, o, b, step_idx))(
            params_r, opt_state_r, batch_r)

    def outer_sync(params_r):
        mean = jax.tree.map(lambda p: p.mean(axis=0, keepdims=True), params_r)
        return jax.tree.map(
            lambda m: jnp.broadcast_to(m, (n_replicas,) + m.shape[1:]), mean)

    return inner, outer_sync
