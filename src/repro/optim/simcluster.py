"""BSP cluster simulator: real convergence curves, modeled wall-clock.

The m "machines" are vmapped lanes of a single jitted step, so the
*algorithmic* trajectory (objective per outer iteration as a function of m)
is exactly what a real m-machine BSP cluster would produce.  Wall-clock is
composed per DESIGN.md §3:

  t_iter(m) = measured_total_compute / m        (perfect compute scaling)
            + comm(m)                            (tree bcast/reduce model)
            + per_task * m + overhead            (driver/scheduler costs)

which is exactly the family Ernest's f(m) = th0 + th1*size/m + th2*log(m)
+ th3*m was designed for.  On a real cluster, replace `iteration_time` with
measured times; nothing downstream changes.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ernest import ErnestModel
from repro.optim.cocoa import CocoaConfig, RunRecord, partition, run_cocoa
from repro.optim.lbfgs import LBFGSConfig, run_lbfgs
from repro.optim.problems import ERMProblem
from repro.optim.sgd import (
    GDConfig,
    LocalSGDConfig,
    SGDConfig,
    run_gd,
    run_local_sgd,
    run_minibatch_sgd,
)

ALGORITHMS = ("cocoa", "cocoa+", "minibatch_sgd", "local_sgd", "gd", "lbfgs")


# ---------------------------------------------------------------------------
# SSP / staleness-aware local-SGD: the stepwise executor the chaos loop
# drives (repro.runtime.chaos).  Unlike the run_* trajectory functions above
# it advances ONE outer iteration at a time, so the control loop can change
# m (elastic resize), H (sync_relax mitigation), and the per-worker sync
# mask (SSP: a straggler skips the barrier, bounded-staleness) mid-run —
# each with a real algorithmic effect on the objective trajectory.
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnums=(0, 4))
def _ssp_outer_step(static, Xs, ys, W, h, mask, lam, t, key):
    """One SSP round: every worker runs h local SGD steps from its own
    (possibly stale) copy; workers with mask=1 push/pull at the barrier."""
    loss, gamma_sm, lr0, t0 = static
    m, nl, _ = Xs.shape
    keys = jax.random.split(key, m)

    def worker(Xk, yk, wk, k):
        idx = jax.random.randint(k, (h,), 0, nl)

        def step(carry, j):
            w_c, i_c = carry
            x, yj = Xk[j], yk[j]
            z = yj * jnp.dot(x, w_c)
            if loss == "hinge":
                gz = jnp.where(z < 1.0, -1.0, 0.0)
            elif loss == "smooth_hinge":
                gz = jnp.where(z >= 1.0, 0.0,
                               jnp.where(z <= 1.0 - gamma_sm, -1.0,
                                         (z - 1.0) / gamma_sm))
            else:
                gz = -jax.nn.sigmoid(-z)
            g = gz * yj * x + lam * w_c
            lr = lr0 / (lam * (t * h + i_c + t0))
            return (w_c - lr * g, i_c + 1.0), None

        (wk2, _), _ = jax.lax.scan(step, (wk, jnp.float32(0.0)), idx)
        return wk2

    W2 = jax.vmap(worker)(Xs, ys, W, keys)           # (m, d) local results
    n_sync = jnp.maximum(jnp.sum(mask), 1.0)
    w_new = jnp.sum(W2 * mask[:, None], axis=0) / n_sync
    # syncing workers pull the fresh average; stale workers keep diverging
    W_next = jnp.where(mask[:, None] > 0, w_new[None, :], W2)
    return W_next, w_new


class SSPLocalSGD:
    """Stepwise staleness-aware local-SGD over m vmapped BSP workers.

    Implements the chaos-loop executor contract: ``outer_step`` advances one
    outer iteration (returns the primal objective at the synced iterate),
    ``resize`` re-shards the data to a new m from the current iterate (what
    the elastic path does from a checkpoint), ``relax`` switches to H>1
    local steps (sync_relax mitigation), and ``checkpoint``/``restore``
    snapshot/rewind the global iterate — a restore genuinely loses the work
    since the last checkpoint, exactly like a real restart.

    Determinism: minibatch draws come from ``fold_in(seed, outer_t)`` so a
    replayed run (same seed, same control actions) is bit-identical.
    """

    def __init__(self, problem: ERMProblem, m: int, *, local_steps: int = 1,
                 lr0: float = 1.0, t0: float = 100.0, seed: int = 0):
        self.problem = problem
        self.local_steps = int(local_steps)
        self.lr0 = float(lr0)
        self.t0 = float(t0)
        self.seed = int(seed)
        self.w = jnp.zeros((problem.d,), jnp.float32)
        self.t = 0                      # outer-iteration counter (lr + PRNG)
        self._key = jax.random.PRNGKey(seed)
        self._ckpt = None
        self._primal = jax.jit(problem.primal)
        self.m = 0
        self.resize(m)

    # -- executor contract ---------------------------------------------
    def resize(self, m: int) -> None:
        """Re-partition the data over m workers, seeding every worker from
        the current global iterate (the elastic re-shard, simulated)."""
        self.m = int(m)
        self.Xs, self.ys = partition(self.problem.X, self.problem.y, self.m)
        self.W = jnp.broadcast_to(self.w, (self.m, self.problem.d))

    def relax(self, local_steps: int) -> None:
        self.local_steps = max(int(local_steps), 1)

    def checkpoint(self) -> None:
        self._ckpt = (np.asarray(self.w), self.t, self.local_steps)

    def restore(self) -> None:
        assert self._ckpt is not None, "no checkpoint to restore"
        w, t, h = self._ckpt
        self.w = jnp.asarray(w)
        self.t = t
        self.local_steps = h
        self.W = jnp.broadcast_to(self.w, (self.m, self.problem.d))

    def outer_step(self, sync_mask: Optional[Sequence[bool]] = None) -> float:
        if sync_mask is None:
            mask = np.ones(self.m, np.float32)
        else:
            mask = np.asarray([1.0 if s else 0.0 for s in sync_mask],
                              np.float32)
            if mask.shape[0] < self.m:       # capacity shrank under us
                mask = np.concatenate(
                    [mask, np.ones(self.m - mask.shape[0], np.float32)])
            mask = mask[:self.m]
        if not mask.any():
            mask[0] = 1.0                    # someone must hold the iterate
        static = (self.problem.loss, self.problem.smooth_gamma,
                  self.lr0, self.t0)
        key = jax.random.fold_in(self._key, self.t)
        self.W, self.w = _ssp_outer_step(
            static, self.Xs, self.ys, self.W, self.local_steps,
            jnp.asarray(mask), self.problem.lam, jnp.float32(self.t), key)
        self.t += 1
        return float(self._primal(self.w))

    # ------------------------------------------------------------------
    def reference_floor(self, iters: int = 300) -> float:
        """Deterministic lower-bound estimate of P* for gap computation:
        full-gradient descent run long, minus a small margin."""
        rec = run_gd(self.problem, GDConfig(outer_iters=iters),
                     record_every=50)
        return float(rec.primal.min()) - 1e-3


@dataclasses.dataclass(frozen=True)
class CommModel:
    """EC2-flavoured BSP communication costs for a d-float model vector."""

    latency_s: float = 5e-4
    bandwidth_Bps: float = 1.2e9
    per_task_s: float = 1.5e-3   # driver-side per-task handling -> theta3 * m
    overhead_s: float = 0.05     # per-iteration scheduling floor -> theta0

    def iteration_comm(self, m: int, nbytes: float) -> float:
        if m <= 1:
            return self.overhead_s
        hops = math.ceil(math.log2(m))
        tree = 2.0 * (self.latency_s * hops + nbytes / self.bandwidth_Bps)
        return self.overhead_s + tree + self.per_task_s * m


@dataclasses.dataclass
class SimResult:
    algorithm: str
    m: int
    record: RunRecord
    t_iter: float              # modeled seconds per outer iteration
    wall_times: np.ndarray     # cumulative modeled wall-clock per recorded iter

    def curve(self) -> np.ndarray:
        return self.record.primal


def run_algorithm(problem: ERMProblem, algorithm: str, m: int,
                  outer_iters: int, seed: int = 0,
                  local_iters: Optional[int] = None,
                  batch_per_worker: int = 64) -> RunRecord:
    if algorithm == "cocoa":
        return run_cocoa(problem, CocoaConfig(m, outer_iters, local_iters,
                                              plus=False, seed=seed))
    if algorithm == "cocoa+":
        return run_cocoa(problem, CocoaConfig(m, outer_iters, local_iters,
                                              plus=True, seed=seed))
    if algorithm == "minibatch_sgd":
        return run_minibatch_sgd(problem, SGDConfig(
            m, outer_iters, batch_per_worker=batch_per_worker, seed=seed))
    if algorithm == "local_sgd":
        return run_local_sgd(problem, LocalSGDConfig(
            m, outer_iters, local_steps=local_iters, seed=seed))
    if algorithm == "gd":
        return run_gd(problem, GDConfig(outer_iters))
    if algorithm == "lbfgs":
        return run_lbfgs(problem, LBFGSConfig(outer_iters))
    raise ValueError(f"unknown algorithm {algorithm!r}; known {ALGORITHMS}")


class BSPCluster:
    def __init__(self, comm: Optional[CommModel] = None):
        self.comm = comm or CommModel()
        self._floor_cache: dict = {}

    def iteration_time(self, m: int, compute_total_s: float, d: int) -> float:
        nbytes = 4.0 * d  # fp32 model vector broadcast + reduce
        return compute_total_s / m + self.comm.iteration_comm(m, nbytes)

    # ------------------------------------------------------------------
    def _dispatch_floor(self, problem: ERMProblem, algorithm: str,
                        m: int) -> float:
        """Fixed per-step host/XLA dispatch cost on this container — NOT part
        of the modeled cluster; calibrated with a near-empty shard and
        subtracted from measured compute (Ernest's size-scaling assumption
        needs per-example work, not the simulator's jit overhead)."""
        key = (algorithm, m)
        if key not in self._floor_cache:
            n_tiny = max(2 * m, 16)
            tiny = ERMProblem(problem.X[:n_tiny], problem.y[:n_tiny],
                              problem.lam, problem.loss, problem.smooth_gamma)
            run_algorithm(tiny, algorithm, m, 1)  # jit warmup
            rec = run_algorithm(tiny, algorithm, m, 3)
            self._floor_cache[key] = rec.compute_seconds / 3.0
        return self._floor_cache[key]

    def _net_compute(self, rec: RunRecord, problem: ERMProblem,
                     algorithm: str, m: int, iters: int) -> float:
        per_iter = rec.compute_seconds / max(iters, 1)
        floor = self._dispatch_floor(problem, algorithm, m)
        return max(per_iter - floor, per_iter * 0.02)

    # ------------------------------------------------------------------
    def simulate(self, problem: ERMProblem, algorithm: str, m: int,
                 outer_iters: int, seed: int = 0,
                 local_iters: Optional[int] = None) -> SimResult:
        run_algorithm(problem, algorithm, m, 1, seed=seed,
                      local_iters=local_iters)  # jit warmup (cold first
        # iterations would fold compile time into the "measured" compute)
        rec = run_algorithm(problem, algorithm, m, outer_iters, seed=seed,
                            local_iters=local_iters)
        per_iter_compute = self._net_compute(rec, problem, algorithm, m,
                                             len(rec.primal))
        t_iter = self.iteration_time(m, per_iter_compute, problem.d)
        wall = np.arange(1, len(rec.primal) + 1) * t_iter
        return SimResult(algorithm, m, rec, t_iter, wall)

    def sweep_parallelism(self, problem: ERMProblem, algorithm: str,
                          ms: Sequence[int], outer_iters: int,
                          seed: int = 0) -> Dict[int, SimResult]:
        return {m: self.simulate(problem, algorithm, m, outer_iters, seed=seed)
                for m in ms}

    # ------------------------------------------------------------------
    # Ernest data acquisition (small m, small data fractions)
    # ------------------------------------------------------------------
    def collect_ernest_samples(
        self, problem: ERMProblem, algorithm: str,
        configs: Sequence[Tuple[int, float]],  # (m, data_fraction)
        iters_per_sample: int = 3, seed: int = 0,
    ) -> List[Tuple[int, float, float]]:
        """Returns (m, size=fraction*n, t_iter) observations."""
        samples = []
        for m, frac in configs:
            n_sub = max(int(problem.n * frac), m * 2)
            sub = ERMProblem(problem.X[:n_sub], problem.y[:n_sub],
                             problem.lam, problem.loss, problem.smooth_gamma)
            run_algorithm(sub, algorithm, m, 1, seed=seed)  # jit warmup
            rec = run_algorithm(sub, algorithm, m, iters_per_sample, seed=seed)
            per_iter = self._net_compute(rec, problem, algorithm, m,
                                         iters_per_sample)
            samples.append((m, float(n_sub),
                            self.iteration_time(m, per_iter, problem.d)))
        return samples

    def fit_ernest(self, samples: Sequence[Tuple[int, float, float]],
                   terms=None) -> ErnestModel:
        m, size, t = zip(*samples)
        model = ErnestModel(terms or ErnestModel().term_names)
        return model.fit(np.asarray(m), np.asarray(size), np.asarray(t))


def solve_reference(problem: ERMProblem, iters: int = 400,
                    seed: int = 0) -> Tuple[float, np.ndarray]:
    """High-accuracy P* via single-machine SDCA (m=1) run long."""
    rec = run_cocoa(problem, CocoaConfig(
        n_workers=1, outer_iters=iters, plus=False, seed=seed))
    return float(rec.primal.min()), rec.w
