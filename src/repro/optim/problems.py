"""L2-regularized ERM problems + the synthetic MNIST stand-in (§2.3).

    P(w) = (1/n) sum_i phi(y_i, x_i . w) + (lam/2) ||w||^2

with hinge (linear SVM, as in the paper), smoothed hinge, or logistic loss.
For SDCA-family solvers we expose the dual objective and duality gap
(Shalev-Shwartz & Zhang 2013 formulation: w(alpha) = X^T alpha / (lam n),
alpha_i * y_i in [0, 1] for hinge).
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Tuple

import jax
import jax.numpy as jnp
import numpy as np

LossName = Literal["hinge", "smooth_hinge", "logistic"]


@dataclasses.dataclass(frozen=True)
class ERMProblem:
    X: jnp.ndarray  # (n, d)
    y: jnp.ndarray  # (n,) in {-1, +1}
    lam: float
    loss: LossName = "hinge"
    smooth_gamma: float = 1.0  # smoothed-hinge smoothing

    @property
    def n(self) -> int:
        return self.X.shape[0]

    @property
    def d(self) -> int:
        return self.X.shape[1]

    # ------------------------------------------------------------------
    def margins(self, w: jnp.ndarray) -> jnp.ndarray:
        return self.y * (self.X @ w)

    def loss_values(self, z: jnp.ndarray) -> jnp.ndarray:
        if self.loss == "hinge":
            return jnp.maximum(0.0, 1.0 - z)
        if self.loss == "smooth_hinge":
            g = self.smooth_gamma
            return jnp.where(
                z >= 1.0, 0.0,
                jnp.where(z <= 1.0 - g, 1.0 - z - g / 2,
                          (1.0 - z) ** 2 / (2 * g)))
        # logistic
        return jnp.logaddexp(0.0, -z)

    def loss_grad_z(self, z: jnp.ndarray) -> jnp.ndarray:
        """d loss / d z (z = y * x.w)."""
        if self.loss == "hinge":
            return jnp.where(z < 1.0, -1.0, 0.0)
        if self.loss == "smooth_hinge":
            g = self.smooth_gamma
            return jnp.where(z >= 1.0, 0.0,
                             jnp.where(z <= 1.0 - g, -1.0, (z - 1.0) / g))
        return -jax.nn.sigmoid(-z)

    def primal(self, w: jnp.ndarray) -> jnp.ndarray:
        z = self.margins(w)
        return jnp.mean(self.loss_values(z)) + 0.5 * self.lam * jnp.sum(w * w)

    def grad(self, w: jnp.ndarray) -> jnp.ndarray:
        z = self.margins(w)
        gz = self.loss_grad_z(z)  # (n,)
        return (self.X.T @ (gz * self.y)) / self.n + self.lam * w

    # ------------------------------------------------------------------
    # SDCA dual (hinge / smooth hinge).  alpha parametrized so that
    # a_i := alpha_i * y_i in [0, 1];  w(alpha) = X^T (a*y) / (lam n).
    # ------------------------------------------------------------------
    def w_of_alpha(self, a: jnp.ndarray) -> jnp.ndarray:
        return self.X.T @ (a * self.y) / (self.lam * self.n)

    def dual(self, a: jnp.ndarray) -> jnp.ndarray:
        w = self.w_of_alpha(a)
        if self.loss == "smooth_hinge":
            conj = a - self.smooth_gamma * a * a / 2.0
        else:  # hinge
            conj = a
        return jnp.mean(conj) - 0.5 * self.lam * jnp.sum(w * w)

    def duality_gap(self, a: jnp.ndarray) -> jnp.ndarray:
        return self.primal(self.w_of_alpha(a)) - self.dual(a)


# ---------------------------------------------------------------------------
# Synthetic MNIST stand-in (MNIST unavailable offline; see DESIGN.md §6)
# ---------------------------------------------------------------------------
def synthetic_mnist(
    n: int = 60_000,
    d: int = 784,
    effective_rank: int = 40,
    positive_fraction: float = 0.09,
    noise: float = 0.35,
    seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray]:
    """Low-rank-ish pixel data + imbalanced binary labels (digit==5 proxy).

    X = |Z W| scaled to [0,1]; labels from a hyperplane on the latent Z,
    thresholded at the (1 - positive_fraction) quantile.
    """
    rng = np.random.RandomState(seed)
    z = rng.randn(n, effective_rank)
    w_mix = rng.randn(effective_rank, d) / np.sqrt(effective_rank)
    x = z @ w_mix + noise * rng.randn(n, d)
    x = np.abs(x)
    x = x / (x.max() + 1e-9)
    direction = rng.randn(effective_rank)
    score = z @ direction
    thresh = np.quantile(score, 1.0 - positive_fraction)
    y = np.where(score >= thresh, 1.0, -1.0)
    return x.astype(np.float32), y.astype(np.float32)


def make_mnist_svm(cfg=None) -> ERMProblem:
    """The paper's workload from configs/cocoa_mnist.py."""
    from repro.configs import cocoa_mnist
    cfg = cfg or cocoa_mnist.config()
    x, y = synthetic_mnist(cfg.n_examples, cfg.n_features, cfg.effective_rank,
                           cfg.positive_fraction, cfg.noise, cfg.seed)
    return ERMProblem(jnp.asarray(x), jnp.asarray(y), lam=cfg.lam, loss="hinge")
