"""CoCoA [NIPS'14] and CoCoA+ [ICML'15] — the paper's main subjects.

Data-parallel dual coordinate ascent: each of the m workers runs H local
SDCA steps on its own partition against a local view
v = w + sigma' * (local delta), then the delta-w's are combined:

  * CoCoA   (gamma = 1/m "averaging", sigma' = 1):  w += mean_k dw_k
  * CoCoA+  (gamma = 1  "adding",    sigma' = m):   w += sum_k dw_k

Convergence genuinely degrades as m grows (fewer, more local updates per
round) — the behavior Hemingway models (Fig 1b).  Workers are vmapped; on a
real mesh the same functions run under shard_map with a psum (see
repro.optim.simcluster.BSPCluster).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.problems import ERMProblem


@dataclasses.dataclass(frozen=True)
class CocoaConfig:
    n_workers: int
    outer_iters: int = 100
    local_iters: Optional[int] = None  # default: one local epoch (n/m steps)
    plus: bool = False                 # CoCoA+ (adding) vs CoCoA (averaging)
    seed: int = 0


def partition(X: jnp.ndarray, y: jnp.ndarray, m: int
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Shard (n, d) -> (m, n_local, d), zero-padding the tail (padded rows
    have ||x|| = 0 and are skipped by the update's curvature guard)."""
    n, d = X.shape
    nl = -(-n // m)
    pad = nl * m - n
    Xp = jnp.pad(X, ((0, pad), (0, 0)))
    yp = jnp.pad(y, (0, pad), constant_values=1.0)
    return Xp.reshape(m, nl, d), yp.reshape(m, nl)


def _local_sdca(problem_static, X_k, y_k, a_k, w, idx, sigma_prime, lam, n):
    """H local SDCA steps on one worker. Returns (a_k, dw_k)."""
    loss, gamma_sm = problem_static

    def step(carry, j):
        a, v = carry
        x = X_k[j]
        yj = y_k[j]
        aj = a[j]
        xx = jnp.dot(x, x)
        q = sigma_prime * xx / (lam * n)
        margin = yj * jnp.dot(v, x)
        if loss == "smooth_hinge":
            delta_raw = (1.0 - margin - gamma_sm * aj) / (q + gamma_sm)
        else:  # hinge
            delta_raw = jnp.where(q > 0, (1.0 - margin) / jnp.maximum(q, 1e-30),
                                  0.0)
        a_new = jnp.clip(aj + delta_raw, 0.0, 1.0)
        delta = jnp.where(xx > 0, a_new - aj, 0.0)
        a = a.at[j].add(delta)
        v = v + sigma_prime * delta * yj * x / (lam * n)
        return (a, v), None

    (a_k, v), _ = jax.lax.scan(step, (a_k, w), idx)
    dw_k = (v - w) / sigma_prime
    return a_k, dw_k


@partial(jax.jit, static_argnums=(0, 5, 6, 7))
def cocoa_outer_step(problem_static, Xs, ys, a, w, plus: bool, lam_n,
                     local_iters, key):
    """One BSP round; Xs (m, nl, d), a (m, nl)."""
    m, nl, _ = Xs.shape
    lam, n = lam_n
    h = local_iters or nl
    sigma_prime = float(m) if plus else 1.0
    keys = jax.random.split(key, m)
    if h <= nl:
        idx = jax.vmap(lambda k: jax.random.permutation(k, nl)[:h])(keys)
    else:
        idx = jax.vmap(lambda k: jax.random.randint(k, (h,), 0, nl))(keys)
    a_new, dw = jax.vmap(
        lambda Xk, yk, ak, ik: _local_sdca(
            problem_static, Xk, yk, ak, w, ik, sigma_prime, lam, n)
    )(Xs, ys, a, idx)
    w_new = w + (jnp.sum(dw, 0) if plus else jnp.mean(dw, 0))
    return a_new, w_new


@dataclasses.dataclass
class RunRecord:
    primal: np.ndarray
    dual: np.ndarray
    gap: np.ndarray
    w: np.ndarray
    compute_seconds: float  # total measured compute across all simulated workers


def run_cocoa(problem: ERMProblem, cfg: CocoaConfig,
              record_every: int = 1) -> RunRecord:
    import time

    m = cfg.n_workers
    Xs, ys = partition(problem.X, problem.y, m)
    nl = Xs.shape[1]
    a = jnp.zeros((m, nl), jnp.float32)
    w = jnp.zeros((problem.d,), jnp.float32)
    key = jax.random.PRNGKey(cfg.seed)
    problem_static = (problem.loss, problem.smooth_gamma)
    lam_n = (problem.lam, float(problem.n))

    primal, dual, gap = [], [], []
    t_compute = 0.0
    for it in range(cfg.outer_iters):
        key, sub = jax.random.split(key)
        t0 = time.perf_counter()
        a, w = cocoa_outer_step(problem_static, Xs, ys, a, w, cfg.plus,
                                lam_n, cfg.local_iters, sub)
        w.block_until_ready()
        t_compute += time.perf_counter() - t0
        if it % record_every == 0 or it == cfg.outer_iters - 1:
            a_flat = a.reshape(-1)[: problem.n]
            primal.append(float(problem.primal(w)))
            dual.append(float(problem.dual(a_flat)))
            gap.append(primal[-1] - dual[-1])
    return RunRecord(np.asarray(primal), np.asarray(dual), np.asarray(gap),
                     np.asarray(w), t_compute)
