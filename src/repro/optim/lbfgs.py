"""Distributed L-BFGS (quasi-Newton baseline, §2.2).

Gradients are computed data-parallel (the expensive part — one pass over the
shards, reduced); the two-loop recursion and line search are on the driver,
as in production L-BFGS-on-Spark/MLlib.  Requires a smooth loss
(logistic / smooth_hinge).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.cocoa import RunRecord
from repro.optim.problems import ERMProblem


@dataclasses.dataclass(frozen=True)
class LBFGSConfig:
    outer_iters: int = 100
    memory: int = 10
    c1: float = 1e-4
    backtrack: float = 0.5
    max_ls: int = 20


def run_lbfgs(problem: ERMProblem, cfg: LBFGSConfig,
              record_every: int = 1) -> RunRecord:
    if problem.loss == "hinge":
        raise ValueError("L-BFGS needs a smooth loss (logistic/smooth_hinge)")
    w = jnp.zeros((problem.d,), jnp.float32)
    value_and_grad = jax.jit(jax.value_and_grad(problem.primal))
    s_list: List[jnp.ndarray] = []
    y_list: List[jnp.ndarray] = []
    primal = []
    t_compute = 0.0
    f, g = value_and_grad(w)
    for it in range(cfg.outer_iters):
        t_start = time.perf_counter()
        # two-loop recursion
        q = g
        alphas = []
        for s, yv in zip(reversed(s_list), reversed(y_list)):
            rho = 1.0 / jnp.maximum(jnp.dot(yv, s), 1e-12)
            a = rho * jnp.dot(s, q)
            alphas.append((a, rho))
            q = q - a * yv
        if y_list:
            gamma = jnp.dot(s_list[-1], y_list[-1]) / jnp.maximum(
                jnp.dot(y_list[-1], y_list[-1]), 1e-12)
            q = gamma * q
        for (a, rho), s, yv in zip(reversed(alphas), s_list, y_list):
            b = rho * jnp.dot(yv, q)
            q = q + (a - b) * s
        direction = -q
        # Armijo backtracking
        step = 1.0
        gtd = jnp.dot(g, direction)
        f_new, g_new, w_new = f, g, w
        for _ in range(cfg.max_ls):
            w_try = w + step * direction
            f_try, g_try = value_and_grad(w_try)
            if float(f_try) <= float(f) + cfg.c1 * step * float(gtd):
                f_new, g_new, w_new = f_try, g_try, w_try
                break
            step *= cfg.backtrack
        else:
            # no sufficient decrease — take a tiny gradient step
            w_new = w - 1e-3 * g
            f_new, g_new = value_and_grad(w_new)
        s_list.append(w_new - w)
        y_list.append(g_new - g)
        if len(s_list) > cfg.memory:
            s_list.pop(0)
            y_list.pop(0)
        w, f, g = w_new, f_new, g_new
        jax.block_until_ready(w)
        t_compute += time.perf_counter() - t_start
        if it % record_every == 0 or it == cfg.outer_iters - 1:
            primal.append(float(f))
    p = np.asarray(primal)
    nan = np.full_like(p, np.nan)
    return RunRecord(p, nan, nan, np.asarray(w), t_compute)
