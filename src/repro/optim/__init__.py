"""Distributed optimization algorithms modeled by Hemingway."""


from repro.optim.cocoa import CocoaConfig, RunRecord, run_cocoa
from repro.optim.lbfgs import LBFGSConfig, run_lbfgs
from repro.optim.problems import ERMProblem, make_mnist_svm, synthetic_mnist
from repro.optim.sgd import (
    GDConfig,
    LocalSGDConfig,
    SGDConfig,
    run_gd,
    run_local_sgd,
    run_minibatch_sgd,
)
from repro.optim.simcluster import (
    ALGORITHMS,
    BSPCluster,
    CommModel,
    SimResult,
    run_algorithm,
    solve_reference,
)

__all__ = [
    "ALGORITHMS",
    "BSPCluster",
    "CocoaConfig",
    "CommModel",
    "ERMProblem",
    "GDConfig",
    "LBFGSConfig",
    "LocalSGDConfig",
    "RunRecord",
    "SGDConfig",
    "SimResult",
    "make_mnist_svm",
    "run_algorithm",
    "run_cocoa",
    "run_gd",
    "run_lbfgs",
    "run_local_sgd",
    "run_minibatch_sgd",
    "solve_reference",
    "synthetic_mnist",
]
