"""Mini-batch SGD, local-update SGD (Splash-like), and full GD baselines.

The paper compares CoCoA/CoCoA+ against parallel SGD with local updates and
Splash (Fig 1c); these are those baselines, vmapped over BSP workers.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.cocoa import RunRecord, partition
from repro.optim.problems import ERMProblem


# ---------------------------------------------------------------------------
# Mini-batch SGD (Pegasos-style step size for SVM)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SGDConfig:
    n_workers: int
    outer_iters: int = 100
    batch_per_worker: int = 64
    lr0: Optional[float] = None  # default 1/(lam * (t + t0))
    t0: float = 100.0
    seed: int = 0


@partial(jax.jit, static_argnums=(0, 4))
def _sgd_step(problem_static, Xs, ys, w, batch_per_worker, lam, t, key):
    loss, gamma_sm, t0, lr0 = problem_static
    m, nl, d = Xs.shape
    keys = jax.random.split(key, m)

    def worker_grad(Xk, yk, k):
        idx = jax.random.randint(k, (batch_per_worker,), 0, nl)
        xb, yb = Xk[idx], yk[idx]
        z = yb * (xb @ w)
        if loss == "hinge":
            gz = jnp.where(z < 1.0, -1.0, 0.0)
        elif loss == "smooth_hinge":
            gz = jnp.where(z >= 1.0, 0.0,
                           jnp.where(z <= 1.0 - gamma_sm, -1.0,
                                     (z - 1.0) / gamma_sm))
        else:
            gz = -jax.nn.sigmoid(-z)
        return xb.T @ (gz * yb) / batch_per_worker

    grads = jax.vmap(worker_grad)(Xs, ys, keys)  # (m, d)
    g = jnp.mean(grads, 0) + lam * w
    lr = lr0 if lr0 is not None else 1.0 / (lam * (t + t0))
    w_new = w - lr * g
    # Pegasos projection onto the ||w|| <= 1/sqrt(lam) ball
    norm = jnp.linalg.norm(w_new)
    return w_new * jnp.minimum(1.0, 1.0 / (jnp.sqrt(lam) * norm + 1e-30))


def run_minibatch_sgd(problem: ERMProblem, cfg: SGDConfig,
                      record_every: int = 1) -> RunRecord:
    m = cfg.n_workers
    Xs, ys = partition(problem.X, problem.y, m)
    w = jnp.zeros((problem.d,), jnp.float32)
    key = jax.random.PRNGKey(cfg.seed)
    static = (problem.loss, problem.smooth_gamma, cfg.t0, cfg.lr0)
    primal = []
    t_compute = 0.0
    for it in range(cfg.outer_iters):
        key, sub = jax.random.split(key)
        t_start = time.perf_counter()
        w = _sgd_step(static, Xs, ys, w, cfg.batch_per_worker, problem.lam,
                      jnp.float32(it + 1), sub)
        w.block_until_ready()
        t_compute += time.perf_counter() - t_start
        if it % record_every == 0 or it == cfg.outer_iters - 1:
            primal.append(float(problem.primal(w)))
    p = np.asarray(primal)
    nan = np.full_like(p, np.nan)
    return RunRecord(p, nan, nan, np.asarray(w), t_compute)


# ---------------------------------------------------------------------------
# Local-update SGD (Splash-like: local passes then averaging)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LocalSGDConfig:
    n_workers: int
    outer_iters: int = 100
    local_steps: Optional[int] = None  # default: one local epoch
    lr0: float = 1.0
    t0: float = 100.0
    seed: int = 0


@partial(jax.jit, static_argnums=(0, 4))
def _local_sgd_step(problem_static, Xs, ys, w, local_steps, lam, t, key):
    loss, gamma_sm, lr0, t0 = problem_static
    m, nl, d = Xs.shape
    h = local_steps or nl
    keys = jax.random.split(key, m)

    def worker(Xk, yk, k):
        if h <= nl:
            idx = jax.random.permutation(k, nl)[:h]
        else:
            idx = jax.random.randint(k, (h,), 0, nl)

        def step(carry, args):
            wk, step_i = carry
            j = args
            x, yj = Xk[j], yk[j]
            z = yj * jnp.dot(x, wk)
            if loss == "hinge":
                gz = jnp.where(z < 1.0, -1.0, 0.0)
            elif loss == "smooth_hinge":
                gz = jnp.where(z >= 1.0, 0.0,
                               jnp.where(z <= 1.0 - gamma_sm, -1.0,
                                         (z - 1.0) / gamma_sm))
            else:
                gz = -jax.nn.sigmoid(-z)
            g = gz * yj * x + lam * wk
            lr = lr0 / (lam * (t * h + step_i + t0))
            return (wk - lr * g, step_i + 1.0), None

        (wk, _), _ = jax.lax.scan(step, (w, jnp.float32(0.0)), idx)
        return wk

    w_locals = jax.vmap(worker)(Xs, ys, keys)  # (m, d)
    return jnp.mean(w_locals, 0)


def run_local_sgd(problem: ERMProblem, cfg: LocalSGDConfig,
                  record_every: int = 1) -> RunRecord:
    m = cfg.n_workers
    Xs, ys = partition(problem.X, problem.y, m)
    w = jnp.zeros((problem.d,), jnp.float32)
    key = jax.random.PRNGKey(cfg.seed)
    static = (problem.loss, problem.smooth_gamma, cfg.lr0, cfg.t0)
    primal = []
    t_compute = 0.0
    for it in range(cfg.outer_iters):
        key, sub = jax.random.split(key)
        t_start = time.perf_counter()
        w = _local_sgd_step(static, Xs, ys, w, cfg.local_steps, problem.lam,
                            jnp.float32(it), sub)
        w.block_until_ready()
        t_compute += time.perf_counter() - t_start
        if it % record_every == 0 or it == cfg.outer_iters - 1:
            primal.append(float(problem.primal(w)))
    p = np.asarray(primal)
    nan = np.full_like(p, np.nan)
    return RunRecord(p, nan, nan, np.asarray(w), t_compute)


# ---------------------------------------------------------------------------
# Full gradient descent (convergence independent of m — §2.2)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class GDConfig:
    outer_iters: int = 100
    lr: float = 0.5


def run_gd(problem: ERMProblem, cfg: GDConfig,
           record_every: int = 1) -> RunRecord:
    w = jnp.zeros((problem.d,), jnp.float32)
    grad = jax.jit(problem.grad)
    primal = []
    t_compute = 0.0
    for it in range(cfg.outer_iters):
        t_start = time.perf_counter()
        w = w - cfg.lr * grad(w)
        w.block_until_ready()
        t_compute += time.perf_counter() - t_start
        if it % record_every == 0 or it == cfg.outer_iters - 1:
            primal.append(float(problem.primal(w)))
    p = np.asarray(primal)
    nan = np.full_like(p, np.nan)
    return RunRecord(p, nan, nan, np.asarray(w), t_compute)
