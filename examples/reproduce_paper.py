"""Reproduce the paper's §2.3 + §4 experiments end to end (scaled to CPU).

Covers: Fig 1a (time/iter vs m), Fig 1b (convergence vs m), Fig 1c
(algorithm comparison), Fig 3 (model fit), Fig 4 (leave-one-m-out),
Fig 5 (forward prediction) and the Ernest accuracy claim.

  PYTHONPATH=src python examples/reproduce_paper.py [--full]

--full uses the paper-scale 60000x784 dataset and m up to 128 (slow on CPU;
the default is a structurally identical scaled-down run).
"""
import os

# keep the examples runnable in CI shells that do not export a JAX
# platform: force CPU before jax (via repro) is ever imported
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse

from benchmarks.context import get_context
from benchmarks import figures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    ctx = get_context(quick=not args.full)
    print(f"\nP* = {ctx.p_star:.6f}  (ms = {ctx.ms})\n")
    for fn in (figures.fig1a_time_per_iter, figures.fig1b_convergence_vs_m,
               figures.fig1c_algorithms, figures.fig3_model_fit,
               figures.fig4_loo_m, figures.fig5_forward_iters,
               figures.fig6_forward_time, figures.ernest_accuracy,
               figures.planner_e2e):
        print(f"--- {fn.__doc__.splitlines()[0]}")
        for name, us, derived in fn(ctx):
            print(f"  {name:32s} {derived}")
    print("\nCompare with the paper: convergence degrades with m (Fig 1b), "
          "CoCoA-family beats SGD (Fig 1c), the lasso fit captures the "
          "curves (Fig 3), extrapolates to held-out m (Fig 4), and "
          "forward-predicts iterations (Fig 5) and wall-clock (Fig 6).")


if __name__ == "__main__":
    main()
