"""A 24h multi-tenant fleet day: 3 training jobs + 2 serving deployments
sharing 24 simulated hosts under diurnal load and injected chaos.

The scheduler places every workload by its Hemingway model (no workload is
executed to discover its needs), preempts training when serving needs the
capacity, resizes jobs against their deadlines, and emits a replayable
``FleetRunLog``.  This script is the acceptance scenario: it asserts

  * every serve deployment meets its p95 latency SLO over the day,
  * every training job reaches epsilon before its deadline or carries an
    explicit typed ``NoFeasiblePlan``,
  * the run log replays bit-identically from the same seed (the guarantee
    the golden fixture tests/fixtures/fleet_golden_seed0.json pins down).

  PYTHONPATH=src python examples/fleet_day.py --seed 0
  PYTHONPATH=src python examples/fleet_day.py --seed 0 --out day.json
  PYTHONPATH=src python examples/fleet_day.py --seed 0 --real-convex
"""
import os

# keep the examples runnable in CI shells that do not export a JAX
# platform: force CPU before jax (via repro) is ever imported
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
from pathlib import Path

GOLDEN = (Path(__file__).resolve().parents[1] / "tests" / "fixtures"
          / "fleet_golden_seed0.json")


def attach_real_convex(jobs):
    """Back job_sweep with a real SSPLocalSGD executor: every scheduler
    resize then re-partitions an actual optimization run (the same
    executor contract launch/train.py's TrainerExecutor implements via
    elastic.rescale_training_state)."""
    import jax.numpy as jnp

    from repro.optim.problems import ERMProblem, synthetic_mnist
    from repro.optim.simcluster import SSPLocalSGD

    X, y = synthetic_mnist(n=256, d=16, effective_rank=8, seed=0)
    problem = ERMProblem(jnp.asarray(X), jnp.asarray(y), lam=1e-2,
                         loss="smooth_hinge")
    for job in jobs:
        if job.name == "job_sweep":
            job.executor = SSPLocalSGD(problem, min(job.m_options),
                                       lr0=0.01, seed=0)
            job.executor.checkpoint()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="write run log JSON here")
    ap.add_argument("--real-convex", action="store_true",
                    help="drive job_sweep with a real SSPLocalSGD executor "
                         "through the elastic resize path")
    ap.add_argument("--no-replay", action="store_true")
    args = ap.parse_args()

    from repro.fleet import FleetSimulator, build_day_scenario, replay
    from repro.launch.fleet import summarize

    trace, jobs, deployments, cfg = build_day_scenario(args.seed)
    if args.real_convex:
        attach_real_convex(jobs)
    log = FleetSimulator(trace, jobs, deployments, cfg).run()
    log.meta.update(seed=args.seed, ticks=trace.steps, scenario="day")
    summarize(log)

    summary = log.meta["summary"]
    for name, d in summary["serve"].items():
        assert d["slo_met"], \
            f"{name} violated its SLO: p95={d['p95_s']:.3f}s > {d['slo_p95_s']}s"
    for name, j in summary["jobs"].items():
        ok = (j["state"] == "done" and j["met_deadline"]) \
            or j["no_plan"] is not None
        assert ok, f"{name}: state={j['state']} with no NoFeasiblePlan record"
    print("acceptance: all serve SLOs met at p95; every training job met "
          "its deadline or holds a typed NoFeasiblePlan ✓")

    if not args.no_replay and not args.real_convex:
        log2 = replay(log)
        assert log.signature() == log2.signature(), \
            "replay diverged from the original run"
        print("replay: identical decision/allocation sequence ✓")
        if args.seed == 0 and GOLDEN.exists():
            from repro.fleet import FleetRunLog
            golden = FleetRunLog.load(GOLDEN)
            # control sequence only: floats are machine-dependent and are
            # compared to tolerance by tests/test_fleet.py instead
            assert log.control_signature() == golden.control_signature(), \
                "run diverged from tests/fixtures/fleet_golden_seed0.json"
            print("golden: matches the checked-in seed-0 fixture ✓")
    if args.out:
        log.save(args.out)
        print(f"run log -> {args.out}")


if __name__ == "__main__":
    main()
