"""Elastic training with the adaptive parallelism controller (§6).

Trains with failure injection AND an AdaptiveController that refits the
convergence model online; when the controller recommends a resize, the
driver checkpoints, changes the data-parallel degree (global batch here),
and resumes — the full elastic loop on CPU.

  PYTHONPATH=src python examples/elastic_train.py
"""
import os

# keep the examples runnable in CI shells that do not export a JAX
# platform: force CPU before jax (via repro) is ever imported
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import tempfile

import numpy as np

from repro.core import AdaptiveController, ErnestModel
from repro.launch.train import Trainer, TrainerOptions
from repro.runtime.failures import FailureInjector


def main():
    sys_model = ErnestModel().fit(
        np.array([1, 2, 4, 8]), np.full(4, 1.0),
        np.array([0.40, 0.22, 0.13, 0.09]))  # measured-ish step times
    ctrl = AdaptiveController(
        sys_model, target_gap=0.05, p_star=0.0, m_options=[1, 2, 4],
        refit_every=15, min_observations=20, reshard_cost_s=1.0)

    with tempfile.TemporaryDirectory() as td:
        m = 1
        opts = TrainerOptions(arch="stablelm-1.6b", smoke=True, steps=40,
                              seq_len=64, global_batch=2 * m, log_every=0,
                              ckpt_dir=td, ckpt_every=10,
                              failure_injector=FailureInjector.at(17))
        trainer = Trainer(opts)
        step_budget = 120
        while trainer.step < step_budget:
            trainer.opts = opts
            n = min(20, step_budget - trainer.step)
            trainer.opts = opts.__class__(**{**opts.__dict__,
                                             "steps": trainer.step + n})
            trainer.tcfg = trainer.tcfg.__class__(
                **{**trainer.tcfg.__dict__,
                   "total_steps": step_budget})
            trainer.run()
            loss = trainer.history[-1][1]
            decision = ctrl.observe(trainer.step, m, loss)
            if decision and decision.resize:
                print(f"[elastic] step {trainer.step}: resize m={m} -> "
                      f"m={decision.target_m} ({decision.reason})")
                m = decision.target_m
                # checkpoint, rebuild at the new parallelism, restore
                trainer._save(block=True)
                new_opts = TrainerOptions(
                    arch="stablelm-1.6b", smoke=True, steps=step_budget,
                    seq_len=64, global_batch=2 * m, log_every=0,
                    ckpt_dir=td, ckpt_every=10)
                trainer = Trainer(new_opts)
                trainer._maybe_restore()
        print(f"done at step {trainer.step}, final loss "
              f"{trainer.history[-1][1]:.3f}, resize decisions: "
              f"{sum(1 for d in ctrl.decisions if d.resize)}")


if __name__ == "__main__":
    main()
