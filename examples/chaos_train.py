"""Closed-loop elastic training on the deterministic chaos simulator (§6).

Generates a seeded fault trace (stragglers, preemptions, slowdowns,
membership churn), then drives the full adaptive loop against it:

    trace -> ClusterSim -> StragglerMonitor / FailureInjector
          -> AdaptiveController (online ConvergenceModel + Ernest refits)
          -> elastic resize / sync_relax / rebalance / hot_spare

and finally REPLAYS the emitted run log from the same seed, asserting the
(m, objective, decision) sequence is bit-identical — the guarantee the
golden-trace regression tests in tests/test_chaos.py are built on.

  PYTHONPATH=src python examples/chaos_train.py --seed 0
  PYTHONPATH=src python examples/chaos_train.py --seed 0 --out run.json
  PYTHONPATH=src python examples/chaos_train.py --seed 0 --lm   # real LM
"""
import os

# keep the examples runnable in CI shells that do not export a JAX
# platform: force CPU before jax (via repro) is ever imported
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse


def summarize(log) -> None:
    steps = log.events("chaos_step")
    wall = steps[-1].wall_s if steps else 0.0
    print(f"steps={len(steps)} mitigations={log.n_mitigations()} "
          f"resizes={log.n_resizes()} final_m={log.meta['final_m']} "
          f"final_objective={log.meta['final_objective']:.4f} "
          f"modeled_wall={wall:.1f}s")
    for r in log.rows:
        tag = r.get("mitigation") or r.get("decision") or r.get("restore")
        if tag:
            print(f"  step {r['step']:4d} m={r['m']} {tag} "
                  f"objective={r['objective']:.4f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--steps", type=int, default=160)
    ap.add_argument("--out", default=None,
                    help="write the run log here (.json for the legacy "
                         "blob, .jsonl for the telemetry event log)")
    ap.add_argument("--lm", action="store_true",
                    help="drive the real (smoke) LM trainer instead of the "
                         "convex BSP simulator")
    ap.add_argument("--no-replay", action="store_true",
                    help="skip the replay determinism check")
    args = ap.parse_args()

    from repro.runtime.chaos import ChaosTrace, replay, run_chaos_sim

    if args.lm:
        import tempfile

        from repro.launch.train import run_chaos_lm
        trace = ChaosTrace.generate(args.seed, args.steps, n_hosts=4)
        with tempfile.TemporaryDirectory() as td:
            log = run_chaos_lm("stablelm-1.6b", trace, td, seed=args.seed)
        summarize(log)
    else:
        log = run_chaos_sim(args.seed, steps=args.steps)
        summarize(log)
        if not args.no_replay:
            log2 = replay(log)
            assert log.signature() == log2.signature(), \
                "replay diverged from the original run"
            print("replay: identical (m, objective, decision) sequence ✓")
    if args.out:
        if str(args.out).endswith(".jsonl"):
            # telemetry event-log form: one typed event per line plus a
            # run_meta header; inspect with `python -m repro.telemetry
            # summarize <out>`
            log.to_jsonl(args.out)
        else:
            log.save(args.out)
        print(f"run log -> {args.out}")


if __name__ == "__main__":
    main()
