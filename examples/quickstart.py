"""Quickstart: the Hemingway loop in ~40 lines.

Simulate CoCoA at a few cluster sizes, fit the system model f(m) and the
convergence model g(i, m), combine into h(t, m) = g(t/f(m), m), and ask the
planner the paper's two questions.

  PYTHONPATH=src python examples/quickstart.py
"""
import os

# keep the examples runnable in CI shells that do not export a JAX
# platform: force CPU before jax (via repro) is ever imported
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax.numpy as jnp
import numpy as np

from repro.core import (CombinedModel, ConvergenceData, ConvergenceModel,
                        ErnestModel, Planner)
from repro.optim import BSPCluster, ERMProblem, synthetic_mnist
from repro.optim.simcluster import solve_reference

# 1. a (synthetic-)MNIST linear SVM, the paper's workload
X, y = synthetic_mnist(n=8_192, d=256, seed=0)
problem = ERMProblem(jnp.asarray(X), jnp.asarray(y), lam=1e-4, loss="hinge")
p_star, _ = solve_reference(problem, iters=150)
print(f"P* = {p_star:.6f}")

# 2. profile a few cluster sizes (real convergence, modeled wall-clock)
cluster = BSPCluster()
ms = [1, 2, 4, 8, 16]
sims = {m: cluster.simulate(problem, "cocoa", m, 40) for m in ms}
for m in ms:
    print(f"m={m:2d}: t_iter={sims[m].t_iter*1e3:7.1f} ms, "
          f"final gap={sims[m].record.primal.min() - p_star:.2e}")

# 3. fit f(m) (Ernest/NNLS) and g(i, m) (LassoCV over phi_j(i, m))
sys_model = ErnestModel().fit(
    np.asarray(ms, float), np.full(len(ms), problem.n, float),
    np.asarray([sims[m].t_iter for m in ms]))
curves = {m: np.minimum.accumulate(s.record.primal) for m, s in sims.items()}
conv_model = ConvergenceModel().fit(
    ConvergenceData.from_curves(curves, p_star - 1e-6, stop_gap=1e-5))
print(f"f(m) coefficients: {sys_model.coefficients()}")
print(f"g(i,m) R^2 = {conv_model.r2(ConvergenceData.from_curves(curves, p_star - 1e-6)):.4f}")

# 4. plan: h(t, m) = g(t / f(m), m)
combined = CombinedModel(sys_model, conv_model, data_size=problem.n,
                         max_iters=10_000)
planner = Planner({"cocoa": combined})
d1 = planner.fastest_to_epsilon(1e-3, m_grid=ms)
assert d1, f"unexpectedly infeasible: {d1.reason}"
print(f"[query 1] eps=1e-3  -> use {d1.algorithm} on m={d1.m} "
      f"(predicted {d1.predicted_time:.2f}s)")
d2 = planner.best_within_budget(5.0, m_grid=ms)
assert d2, f"unexpectedly infeasible: {d2.reason}"
print(f"[query 2] t<=5s     -> use {d2.algorithm} on m={d2.m} "
      f"(predicted objective {d2.predicted_value:.5f})")
