"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Defines a custom ~107M config (stablelm-family), trains with checkpointing
and the full trainer stack.  On this 1-core CPU container a 107M model runs
~1 step/minute, so the default invocation uses --scale 0.25 (a ~10M model,
identical code path) for a few hundred steps; pass --scale 1 on real
hardware.

  PYTHONPATH=src python examples/train_100m.py --steps 200
"""
import os

# keep the examples runnable in CI shells that do not export a JAX
# platform: force CPU before jax (via repro) is ever imported
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import tempfile

from repro.configs.base import ArchConfig, LayerSpec
from repro.launch.train import Trainer, TrainerOptions


def config_100m(scale: float = 1.0) -> ArchConfig:
    d = max(int(512 * scale) // 64 * 64, 128)
    return ArchConfig(
        name=f"lm-100m-s{scale}",
        family="dense",
        n_layers=12 if scale >= 1 else 6,
        d_model=d,
        n_heads=max(d // 64, 2),
        n_kv_heads=max(d // 64, 2),
        head_dim=64,
        d_ff=3 * d,
        vocab_size=32_000 if scale >= 1 else 8_000,
        period=(LayerSpec(mixer="attn", ffn="dense"),),
        source="custom ~100M example",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=4)
    args = ap.parse_args()
    cfg = config_100m(args.scale)
    n = cfg.param_count()
    print(f"model: {cfg.name}: {n/1e6:.1f}M params")
    with tempfile.TemporaryDirectory() as td:
        opts = TrainerOptions(arch="stablelm-1.6b", smoke=True,
                              steps=args.steps, seq_len=args.seq_len,
                              global_batch=args.global_batch,
                              ckpt_dir=td, ckpt_every=50, log_every=20)
        trainer = Trainer(opts)
        # swap in the custom config (the Trainer API takes arch ids; for a
        # custom config we rebuild its model in place)
        from repro.models.model import LM
        from repro.models.runtime import Runtime
        from repro.data.pipeline import SyntheticTokens
        trainer.cfg = cfg
        trainer.lm = LM(cfg, Runtime(remat="none", block_q=64, block_k=64))
        trainer.data = SyntheticTokens(cfg.vocab_size, args.seq_len,
                                       args.global_batch, seed=0)
        trainer._build_state()
        trainer._step_fn = trainer._make_step()
        trainer.run()
        losses = [l for _, l in trainer.history]
        print(f"trained {trainer.step} steps: loss {losses[0]:.3f} -> "
              f"{losses[-1]:.3f}")


if __name__ == "__main__":
    main()
