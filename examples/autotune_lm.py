"""Hemingway for LM training (§6 "non-convex" extension, built).

Collects REAL loss curves from a tiny LM trained at several data-parallel
degrees m (same tokens-per-shard, so m scales the global batch — the modern
"degree of parallelism"), fits g(i, m) on log(loss - floor), fits f(m) from
the BSP comm model, and picks the m that reaches a target loss fastest.

  PYTHONPATH=src python examples/autotune_lm.py
"""
import os

# keep the examples runnable in CI shells that do not export a JAX
# platform: force CPU before jax (via repro) is ever imported
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np

from repro.core import (CombinedModel, ConvergenceData, ConvergenceModel,
                        ErnestModel, Planner)
from repro.launch.train import Trainer, TrainerOptions
from repro.optim.simcluster import CommModel


def loss_curve(m: int, steps: int = 60) -> np.ndarray:
    opts = TrainerOptions(arch="stablelm-1.6b", smoke=True, steps=steps,
                          seq_len=64, global_batch=2 * m, log_every=0,
                          seed=1)
    t = Trainer(opts)
    t.run()
    return np.asarray([l for _, l in t.history])


def main():
    ms = [1, 2, 4]
    print("training tiny LM at data-parallel degrees", ms)
    curves = {}
    compute_s = {}
    for m in ms:
        import time
        t0 = time.time()
        curves[m] = np.minimum.accumulate(loss_curve(m))
        compute_s[m] = (time.time() - t0) / len(curves[m])
        print(f"  m={m}: final loss {curves[m][-1]:.3f} "
              f"({compute_s[m]*1e3:.0f} ms/step measured)")

    # convergence model on log(loss - floor)
    floor = min(c.min() for c in curves.values()) - 0.05
    data = ConvergenceData.from_curves(curves, floor)
    conv = ConvergenceModel().fit(data)
    print(f"g(i,m) R^2 = {conv.r2(data):.4f}; "
          f"active: {sorted(conv.active_features())}")

    # system model: measured per-step compute (scales with local batch ~const
    # here) + BSP comm model for the 1.6B-param gradient sync
    comm = CommModel()
    grad_bytes = 4.0 * 120e6  # smoke model grads
    times = [compute_s[m] + comm.iteration_comm(m, grad_bytes) for m in ms]
    sysm = ErnestModel().fit(np.asarray(ms, float),
                             np.full(len(ms), 1.0), np.asarray(times))

    target = float(np.median([c[-1] for c in curves.values()])) + 0.1
    planner = Planner({"adamw-dp": CombinedModel(sysm, conv, 1.0, 5_000)})
    d = planner.fastest_to_epsilon(target - floor, m_grid=[1, 2, 4, 8])
    assert d, f"unexpectedly infeasible: {d.reason}"
    print(f"target loss {target:.3f}: planner picks m={d.m} "
          f"(predicted {d.predicted_time:.1f}s) — note m=8 was never run; "
          "the model extrapolated it (paper §4.1).")


if __name__ == "__main__":
    main()
