"""Roofline table builder: reads results/dryrun/*.json -> EXPERIMENTS table.

Per (arch x shape x mesh): the three roofline terms, the dominant one,
MODEL_FLOPS/HLO_FLOPS (useful-compute ratio), and bytes/device.

With ``--tune-cache`` it also prints a per-kernel table from the
autotuner's config cache — measured us vs the same light-speed model the
tuner pruned candidates with (``repro.kernels.tune.roofline``), so whole-
program and per-kernel rooflines read off one module.

Run:  PYTHONPATH=src python -m benchmarks.roofline [--dir results/dryrun]
          [--tune-cache results/tune_cache.json]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List


def load_results(directory: str = "results/dryrun",
                 mesh: str = "single") -> List[Dict]:
    rows = []
    for p in sorted(Path(directory).glob(f"*__{mesh}.json")):
        r = json.loads(p.read_text())
        if r.get("status") == "ok":
            rows.append(r)
    return rows


def fmt_row(r: Dict) -> str:
    tc, tm, tl = r["t_compute_s"], r["t_memory_s"], r["t_collective_s"]
    ratio = r.get("useful_flops_ratio") or 0.0
    t_step = max(tc, tm, tl)
    frac = (r["model_flops"] / (r["chips"] * 197e12)) / t_step if t_step else 0
    return (f"| {r['arch']:22s} | {r['shape']:11s} | {tc:.3e} | {tm:.3e} | "
            f"{tl:.3e} | {r['dominant']:10s} | {ratio:6.3f} | {frac:6.3f} |")


HEADER = ("| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) "
          "| dominant | 6ND/HLO | roofline frac |\n"
          "|---|---|---|---|---|---|---|---|")


def roofline_fraction(r: Dict) -> float:
    """Model-FLOPs time at peak / modeled step time (max of terms)."""
    t_step = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
    ideal = r["model_flops"] / (r["chips"] * 197e12)
    return ideal / t_step if t_step > 0 else 0.0


def tune_cache_table(path: str) -> List[str]:
    """Per-kernel measured-vs-light-speed lines from an autotuner cache."""
    from repro.kernels.tune import ConfigCache
    from repro.kernels.tune.roofline import estimate, light_speed_s

    cache = ConfigCache(path)
    lines = ["| family | shape | config | measured (us) | light-speed (us) "
             "| x |", "|---|---|---|---|---|---|"]
    for key in sorted(cache.entries):
        e = cache.entries[key]
        est = estimate(e["family"], e["shape"], e["config"])
        floor_us = light_speed_s(est.flops, est.bytes_moved) * 1e6
        cfg = ";".join(f"{k}={v}" for k, v in sorted(e["config"].items()))
        ratio = e["us_per_call"] / floor_us if floor_us else 0.0
        lines.append(
            f"| {e['family']:18s} | {key.split('|', 2)[1]:28s} | {cfg:20s} "
            f"| {e['us_per_call']:10.1f} | {floor_us:10.3f} "
            f"| {ratio:8.0f} |")
    return lines


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tune-cache", default=None, metavar="PATH",
                    help="also print the per-kernel autotuner table")
    args = ap.parse_args()
    rows = load_results(args.dir, args.mesh)
    print(HEADER)
    for r in rows:
        print(fmt_row(r))
    if rows:
        fracs = sorted(((roofline_fraction(r), r["arch"], r["shape"])
                        for r in rows))
        print(f"\n# {len(rows)} cells; worst roofline fraction: "
              f"{fracs[0][1]} {fracs[0][2]} = {fracs[0][0]:.4f}")
        coll = sorted(((r["t_collective_s"] / max(max(r["t_compute_s"],
                        r["t_memory_s"], r["t_collective_s"]), 1e-30),
                        r["arch"], r["shape"]) for r in rows), reverse=True)
        print(f"# most collective-bound: {coll[0][1]} {coll[0][2]} "
              f"(coll share {coll[0][0]:.2f})")
    if args.tune_cache:
        print()
        for line in tune_cache_table(args.tune_cache):
            print(line)


if __name__ == "__main__":
    main()
