import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing harness.

Selects the three target cells from the baseline roofline table (worst
roofline fraction / most collective-bound / most representative of the
paper's technique), then lowers + analyzes variants, recording
hypothesis -> change -> before -> after for EXPERIMENTS.md §Perf.

  PYTHONPATH=src python -m benchmarks.hillclimb --select       # pick cells
  PYTHONPATH=src python -m benchmarks.hillclimb --run absorb   # one variant
  PYTHONPATH=src python -m benchmarks.hillclimb --drill ARCH SHAPE [METRIC]
"""
import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp

from benchmarks.roofline import load_results, roofline_fraction
from repro.launch.dryrun import analyze, lower_cell, run_cell

OUT = Path("results/dryrun")


def select():
    rows = load_results(str(OUT), "single")
    frac = sorted((roofline_fraction(r), r["arch"], r["shape"]) for r in rows)
    coll = sorted(((r["t_collective_s"] /
                    max(r["t_compute_s"], r["t_memory_s"],
                        r["t_collective_s"], 1e-30), r["arch"], r["shape"])
                   for r in rows), reverse=True)
    print("worst roofline fraction:")
    for f, a, s in frac[:5]:
        print(f"  {f:8.4f}  {a:24s} {s}")
    print("most collective-bound:")
    for c, a, s in coll[:5]:
        print(f"  {c:8.3f}  {a:24s} {s}")
    print("paper-representative: qwen3-14b train_4k (DiLoCo/local-SGD sync "
          "amortization = CoCoA's communication-efficiency axis)")


def drill(arch: str, shape: str, metric: str = "bytes", multi=False):
    from repro.dist.hlo_costs import top_contributors
    lowered, compiled, ctx = lower_cell(arch, shape, multi)
    r = analyze(lowered, compiled, ctx)
    print(f"compute={r['t_compute_s']:.3f}s mem={r['t_memory_s']:.3f}s "
          f"coll={r['t_collective_s']:.3f}s dom={r['dominant']} "
          f"useful={r['useful_flops_ratio']}")
    for v, label, comp in top_contributors(compiled.as_text(), metric, 15):
        print(f"{v/1e9:10.2f} GB|GF  {label[:100]}  {comp}")


def run_variant(arch: str, shape: str, tag: str, runtime_overrides=None,
                rules_overrides=None, multi=False, serve_params_bf16=False):
    r = run_cell(arch, shape, "multi" if multi else "single", OUT,
                 force=True, rules_overrides=rules_overrides,
                 runtime_overrides=runtime_overrides, tag=tag,
                 serve_params_bf16=serve_params_bf16)
    if r.get("status") == "ok":
        print(f"[{tag}] compute={r['t_compute_s']:.3f}s "
              f"mem={r['t_memory_s']:.3f}s coll={r['t_collective_s']:.3f}s "
              f"dom={r['dominant']}")
    else:
        print(f"[{tag}] FAILED: {r.get('error')}")
    return r


def run_diloco(arch: str = "qwen3-14b", n_replicas: int = 16):
    """Paper-representative variant: DiLoCo inner step (no data-axis grad
    sync) on train_4k; collective bytes compared against the synchronous
    baseline.  Outer sync amortization computed analytically (1/H)."""
    from repro.configs import get_config
    from repro.configs.base import SHAPES_BY_NAME
    from repro.dist.partitioning import Rules
    from repro.launch.inputs import batch_sds, opt_state_sds, params_sds
    from repro.launch.mesh import make_production_mesh
    from repro.models.model import LM
    from repro.models.runtime import Runtime
    from repro.training.optimizers import get_optimizer
    from repro.training.trainer import (TrainConfig, make_diloco_inner_step)
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = get_config(arch)
    shape = SHAPES_BY_NAME["train_4k"]
    mesh = make_production_mesh(multi_pod=False)
    # params replicated per data shard (leading replica axis over 'data'),
    # TP over model within each replica; batch has NO data sharding inside
    rules = Rules.default(mesh).override(
        params={"embed": None},            # no FSDP: each replica holds fp32
        acts={"batch": None},              # per-replica batch unsharded
    )
    rt = Runtime(mesh=mesh, rules=rules, remat="full")
    lm = LM(cfg, rt)
    opt = get_optimizer("adamw")
    p_sds, p_axes = params_sds(lm, mesh, rules)
    o_sds = opt_state_sds(opt, p_sds, p_axes, mesh, rules)
    b_sds = batch_sds(cfg, shape, None, rules)

    def add_replica(sds, extra=()):
        spec = sds.sharding.spec if sds.sharding is not None else P()
        new_spec = P("data", *tuple(spec))
        return jax.ShapeDtypeStruct((n_replicas,) + sds.shape, sds.dtype,
                                    sharding=NamedSharding(mesh, new_spec))

    pr = jax.tree.map(add_replica, p_sds)
    orr = jax.tree.map(add_replica, o_sds)
    br = {k: jax.ShapeDtypeStruct(
        (n_replicas, v.shape[0] // n_replicas) + v.shape[1:], v.dtype,
        sharding=NamedSharding(mesh, P("data", None, *([None] * (len(v.shape) - 1)))))
        for k, v in b_sds.items()}
    inner, _ = make_diloco_inner_step(lm, opt, TrainConfig(), n_replicas)
    with mesh:
        lowered = jax.jit(inner, donate_argnums=(0, 1)).lower(
            pr, orr, br, jax.ShapeDtypeStruct((), jnp.int32))
        compiled = lowered.compile()
        ctx = {"cfg": cfg, "shape": shape, "mesh": mesh, "rules": rules,
               "optimizer": "adamw"}
        r = analyze(lowered, compiled, ctx)
    r["status"] = "ok"
    r["variant"] = f"diloco_r{n_replicas}"
    out = OUT / f"{arch}__train_4k__single-diloco.json"
    out.write_text(json.dumps(r, indent=2))
    print(f"[diloco] compute={r['t_compute_s']:.3f}s mem={r['t_memory_s']:.3f}s "
          f"coll={r['t_collective_s']:.3f}s dom={r['dominant']}")
    return r


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--select", action="store_true")
    ap.add_argument("--drill", nargs="+")
    ap.add_argument("--absorb", action="store_true")
    ap.add_argument("--diloco", action="store_true")
    ap.add_argument("--variant", nargs=3, metavar=("ARCH", "SHAPE", "TAG"))
    ap.add_argument("--runtime", type=json.loads, default=None)
    ap.add_argument("--rules", type=json.loads, default=None)
    ap.add_argument("--serve-bf16", action="store_true")
    args = ap.parse_args()
    if args.select:
        select()
    if args.drill:
        drill(args.drill[0], args.drill[1],
              args.drill[2] if len(args.drill) > 2 else "bytes")
    if args.absorb:
        run_variant("deepseek-v2-236b", "decode_32k", "absorb",
                    runtime_overrides={"mla_absorb": True})
    if args.diloco:
        run_diloco()
    if args.variant:
        run_variant(args.variant[0], args.variant[1], args.variant[2],
                    runtime_overrides=args.runtime,
                    rules_overrides=args.rules,
                    serve_params_bf16=args.serve_bf16)


if __name__ == "__main__":
    main()
