"""One benchmark per paper table/figure.  Each returns CSV rows
(name, us_per_call, derived)."""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from benchmarks.context import BenchContext
from repro.core import (
    CombinedModel,
    ConvergenceModel,
    Planner,
    r2_score,
)

Row = Tuple[str, float, str]


# ---------------------------------------------------------------------------
def fig1a_time_per_iter(ctx: BenchContext) -> List[Row]:
    """Fig 1a: time per CoCoA iteration vs degree of parallelism (u-shape)."""
    rows = []
    for m in ctx.ms:
        t = ctx.sims["cocoa"][m].t_iter
        rows.append((f"fig1a/time_per_iter_m{m}", t * 1e6, f"t_iter_s={t:.4f}"))
    ts = [ctx.sims["cocoa"][m].t_iter for m in ctx.ms]
    argmin = ctx.ms[int(np.argmin(ts))]
    rows.append(("fig1a/optimal_m", float(argmin), f"fastest_m={argmin}"))
    return rows


def fig1b_convergence_vs_m(ctx: BenchContext) -> List[Row]:
    """Fig 1b: iterations to reach a target gap degrade with m."""
    rows = []
    target = 1e-3
    for m in ctx.ms:
        gaps = np.minimum.accumulate(ctx.sims["cocoa"][m].record.primal) \
            - ctx.p_star
        hit = np.nonzero(gaps <= target)[0]
        iters = int(hit[0]) + 1 if len(hit) else -1
        rows.append((f"fig1b/iters_to_1e-3_m{m}",
                     ctx.sims["cocoa"][m].t_iter * 1e6,
                     f"iters={iters};final_gap={gaps[-1]:.2e}"))
    return rows


def fig1c_algorithms(ctx: BenchContext) -> List[Row]:
    """Fig 1c: algorithm comparison at m=16: CoCoA-family beats SGD-family."""
    m = 16 if 16 in ctx.ms else max(ctx.ms)
    rows = []
    for algo in ("cocoa", "cocoa+", "local_sgd", "minibatch_sgd"):
        sim = ctx.sims[algo].get(m) or next(iter(ctx.sims[algo].values()))
        gap = float(np.minimum.accumulate(sim.record.primal)[-1] - ctx.p_star)
        rows.append((f"fig1c/{algo}_m{m}", sim.t_iter * 1e6,
                     f"final_gap={gap:.3e}"))
    return rows


def _fit_quality(y_true: np.ndarray, y_pred: np.ndarray) -> str:
    """R² when the target has real variance, RMSE otherwise (curves
    truncated at the 1e-4 target can be near-constant in log-gap, where R²
    is undefined/meaningless)."""
    rmse = float(np.sqrt(np.mean((y_true - y_pred) ** 2)))
    if len(y_true) >= 6 and float(np.var(y_true)) > 1e-3:
        return f"r2={r2_score(y_true, y_pred):.4f}"
    return f"rmse_log={rmse:.4f}(low-variance)"


def fig3_model_fit(ctx: BenchContext) -> List[Row]:
    """Fig 3: Hemingway convergence-model fit quality per m."""
    import time
    data = ctx.convergence_data("cocoa+")
    t0 = time.perf_counter()
    model = ConvergenceModel().fit(data)
    fit_us = (time.perf_counter() - t0) * 1e6
    rows = [("fig3/global_r2", fit_us, f"r2={model.r2(data):.4f}")]
    for m in ctx.ms:
        sub = data.mask(data.m == m)
        if len(sub.i) < 3:
            continue
        pred = model.predict_log_gap(sub.i, sub.m)
        rows.append((f"fig3/fit_m{m}", fit_us,
                     _fit_quality(np.log(sub.gap()), pred)))
    active = ",".join(sorted(model.active_features()))
    rows.append(("fig3/active_features", 0.0, active))
    return rows


def fig4_loo_m(ctx: BenchContext) -> List[Row]:
    """Fig 4: leave-one-m-out prediction of an unobserved parallelism."""
    data = ctx.convergence_data("cocoa+")
    rows = []
    for m_hold in sorted(set(data.m.astype(int))):
        train = data.mask(data.m != m_hold)
        test = data.mask(data.m == m_hold)
        model = ConvergenceModel().fit(train)
        pred = model.predict_log_gap(test.i, test.m)
        rows.append((f"fig4/loo_m{m_hold}", 0.0,
                     "heldout_" + _fit_quality(np.log(test.gap()), pred)))
    return rows


def fig5_forward_iters(ctx: BenchContext) -> List[Row]:
    """Fig 5: forward prediction 1 / 10 iterations ahead (window=|iters|/2)."""
    m = 16 if 16 in ctx.ms else max(ctx.ms)
    data = ctx.convergence_data("cocoa+", stop_gap=None)
    data = data.mask(data.m == m)
    rows = []
    window = max(10, ctx.outer_iters // 3)
    for ahead in (1, 10):
        res = ConvergenceModel().forward_prediction(data, window=window,
                                                    ahead=ahead)
        if m not in res:
            rows.append((f"fig5/ahead{ahead}_m{m}", 0.0, "insufficient"))
            continue
        pred = res[m]
        rel = np.abs(pred[:, 2] - pred[:, 1]) / np.maximum(
            np.abs(pred[:, 1]), 1e-12)
        rows.append((f"fig5/ahead{ahead}_m{m}", 0.0,
                     f"median_rel_err={np.median(rel):.4f};n={len(rel)}"))
    return rows


def fig6_forward_time(ctx: BenchContext) -> List[Row]:
    """Fig 6: Ernest x Hemingway — predict the objective 1s / 5s in the
    future from the model pair."""
    m = 16 if 16 in ctx.ms else max(ctx.ms)
    data = ctx.convergence_data("cocoa+")
    conv = ConvergenceModel().fit(data)
    sysm = ctx.ernest_model("cocoa+")
    cm = CombinedModel(sysm, conv, data_size=ctx.problem.n,
                       max_iters=100_000)
    sim = ctx.sims["cocoa+"][m]
    truth = np.minimum.accumulate(sim.record.primal)
    wall = sim.wall_times
    rows = []
    for dt in (1.0, 5.0):
        errs = []
        for i in range(len(wall)):
            t_future = wall[i] + dt
            j = np.searchsorted(wall, t_future)
            if j >= len(wall):
                break
            pred = float(cm.h(t_future, m)[0])
            errs.append(abs(pred - truth[j]) / max(abs(truth[j]), 1e-12))
        if errs:
            rows.append((f"fig6/ahead_{dt:.0f}s_m{m}", 0.0,
                         f"median_rel_err={np.median(errs):.4f};n={len(errs)}"))
    return rows


def ernest_accuracy(ctx: BenchContext) -> List[Row]:
    """§3.2.1: fit Ernest from small samples (<=10% data), predict the
    full-data sweep; paper reports <=12% error for mini-batch SGD.  Sample
    configs come from the §6 experiment-design answer (greedy D-optimal):
    small-m-only samples cannot identify the log(m)/m communication terms."""
    from repro.core import default_candidate_grid, greedy_d_optimal
    cands = default_candidate_grid(max_m=min(64, max(ctx.ms)),
                                   sizes=(0.05, 0.1))
    chosen = greedy_d_optimal(cands, budget=200.0)
    samples = ctx.cluster.collect_ernest_samples(
        ctx.problem, "cocoa", [(c.m, c.size) for c in chosen],
        iters_per_sample=3)
    model = ctx.cluster.fit_ernest(samples)
    ms = np.asarray(ctx.ms, float)
    true_t = np.asarray([ctx.sims["cocoa"][m].t_iter for m in ctx.ms])
    pred_t = model.predict(ms, np.full(len(ms), ctx.problem.n, float))
    errs = np.abs(pred_t - true_t) / true_t * 100
    return [("ernest/max_pct_err", 0.0, f"max={errs.max():.1f}%"),
            ("ernest/median_pct_err", 0.0, f"median={np.median(errs):.1f}%"),
            ("ernest/coeffs", 0.0,
             ";".join(f"{k}={v:.2e}" for k, v in
                      model.coefficients().items()))]


def planner_e2e(ctx: BenchContext) -> List[Row]:
    """§3.1 end-to-end: planner picks (algorithm, m); compare against the
    oracle (true fastest config in the simulated sweep)."""
    rows = []
    models = {}
    for algo in ("cocoa", "cocoa+"):
        data = ctx.convergence_data(algo)
        conv = ConvergenceModel().fit(data)
        models[algo] = CombinedModel(ctx.ernest_model(algo), conv,
                                     data_size=ctx.problem.n,
                                     max_iters=50_000)
    planner = Planner(models)
    eps = 1e-3
    decision = planner.fastest_to_epsilon(eps, m_grid=list(ctx.ms))
    if not decision:   # NoFeasiblePlan -> surface as this figure's ERROR row
        raise RuntimeError(f"planner infeasible: {decision.reason}")
    # oracle: true time to reach eps from the simulated curves
    oracle = {}
    for algo in ("cocoa", "cocoa+"):
        for m in ctx.ms:
            sim = ctx.sims[algo][m]
            gaps = np.minimum.accumulate(sim.record.primal) - ctx.p_star
            hit = np.nonzero(gaps <= eps)[0]
            if len(hit):
                oracle[(algo, m)] = (int(hit[0]) + 1) * sim.t_iter
    if oracle:
        best = min(oracle, key=oracle.get)
        chosen_true = oracle.get((decision.algorithm, decision.m))
        regret = (chosen_true / oracle[best] if chosen_true is not None
                  else float("inf"))
        rows.append(("planner/chosen", 0.0,
                     f"{decision.algorithm}@m={decision.m};"
                     f"pred_t={decision.predicted_time:.2f}s"))
        rows.append(("planner/oracle", 0.0,
                     f"{best[0]}@m={best[1]};true_t={oracle[best]:.2f}s"))
        rows.append(("planner/regret", 0.0, f"regret_x={regret:.2f}"))
    return rows


def budget_query(ctx: BenchContext) -> List[Row]:
    """§3.1 second query type: best objective within a latency budget."""
    data = ctx.convergence_data("cocoa+")
    conv = ConvergenceModel().fit(data)
    cm = CombinedModel(ctx.ernest_model("cocoa+"), conv,
                       data_size=ctx.problem.n, max_iters=50_000)
    planner = Planner({"cocoa+": cm})
    rows = []
    for budget in (2.0, 10.0):
        d = planner.best_within_budget(budget, m_grid=list(ctx.ms))
        if not d:
            raise RuntimeError(f"budget query infeasible: {d.reason}")
        rows.append((f"planner/budget_{budget:.0f}s", 0.0,
                     f"m={d.m};pred_value={d.predicted_value:.4f}"))
    return rows
