"""Shared benchmark context: one parallelism sweep reused by every figure.

The paper's experiments all derive from CoCoA/CoCoA+ runs on MNIST at
m = 1..128; we run the same sweep once on the synthetic stand-in (scaled to
CPU budget) and hand the curves to each figure's benchmark.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import ConvergenceData, ErnestModel
from repro.optim import BSPCluster, ERMProblem, synthetic_mnist
from repro.optim.simcluster import SimResult, solve_reference


@dataclasses.dataclass
class BenchContext:
    problem: ERMProblem
    cluster: BSPCluster
    p_star: float
    ms: Tuple[int, ...]
    sims: Dict[str, Dict[int, SimResult]]  # algorithm -> m -> result
    outer_iters: int

    def curves(self, algorithm: str = "cocoa+") -> Dict[int, np.ndarray]:
        return {m: np.minimum.accumulate(s.record.primal)
                for m, s in self.sims[algorithm].items()}

    def convergence_data(self, algorithm: str = "cocoa+",
                         stop_gap: Optional[float] = 1e-4) -> ConvergenceData:
        return ConvergenceData.from_curves(
            self.curves(algorithm), self.p_star - 1e-6, stop_gap=stop_gap)

    def ernest_model(self, algorithm: str = "cocoa+") -> ErnestModel:
        ms = sorted(self.sims[algorithm])
        t = [self.sims[algorithm][m].t_iter for m in ms]
        return ErnestModel().fit(np.asarray(ms, float),
                                 np.full(len(ms), self.problem.n, float),
                                 np.asarray(t))


_CTX: Optional[BenchContext] = None


def get_context(quick: bool = False) -> BenchContext:
    global _CTX
    if _CTX is not None:
        return _CTX
    t0 = time.time()
    if quick:
        n, d, ms, iters = 4096, 128, (1, 2, 4, 8, 16), 30
    else:
        n, d, ms, iters = 16_384, 256, (1, 2, 4, 8, 16, 32, 64, 128), 60
    X, y = synthetic_mnist(n, d, 40, 0.09, 0.35, 0)
    problem = ERMProblem(jnp.asarray(X), jnp.asarray(y), lam=1e-4,
                         loss="hinge")
    cluster = BSPCluster()
    p_star, _ = solve_reference(problem, iters=max(3 * iters, 150))
    sims: Dict[str, Dict[int, SimResult]] = {}
    for algo in ("cocoa", "cocoa+"):
        sims[algo] = {m: cluster.simulate(problem, algo, m, iters, seed=1)
                      for m in ms}
    # Fig 1c comparison set at m=16 (or max available)
    m_cmp = 16 if 16 in ms else max(ms)
    for algo in ("local_sgd", "minibatch_sgd"):
        sims[algo] = {m_cmp: cluster.simulate(problem, algo, m_cmp, iters,
                                              seed=1)}
    print(f"# context built in {time.time() - t0:.0f}s "
          f"(n={n}, d={d}, ms={ms}, iters={iters})", flush=True)
    _CTX = BenchContext(problem, cluster, p_star, tuple(ms), sims, iters)
    return _CTX
