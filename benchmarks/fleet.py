"""Fleet scheduler benchmarks: decision throughput + end-to-end day cost.

Rows (pure-python: gated by benchmarks/compare.py against the newest
BENCH_*.json baseline):

  fleet/sched_tick   — mean microseconds per scheduler tick (the placement
                       hot path: capacity planning, admission, resize)
  fleet/day_e2e      — wall microseconds for the canonical 24h seed-0 day
  fleet/day_cost     — derived fleet-efficiency metric: host-hours spent,
                       SLO outcome, decision count (not a timing row)
"""
from __future__ import annotations

import time
from typing import List, Tuple

Row = Tuple[str, float, str]


def bench_fleet() -> List[Row]:
    from repro.fleet import run_fleet_sim

    rows: List[Row] = []
    run_fleet_sim(0, ticks=24)   # warmup: imports, one NNLS fit round

    t0 = time.perf_counter()
    log = run_fleet_sim(0)
    day_s = time.perf_counter() - t0
    ticks = len(log.rows)
    s = log.meta["summary"]

    rows.append(("fleet/sched_tick", day_s / ticks * 1e6,
                 f"ticks={ticks};decisions={log.n_decisions()}"))
    rows.append(("fleet/day_e2e", day_s * 1e6,
                 f"ticks={ticks};hosts={log.trace.n_hosts}"))
    slo_ok = all(d["slo_met"] for d in s["serve"].values())
    jobs_ok = all(j["state"] == "done" and j["met_deadline"]
                  for j in s["jobs"].values())
    rows.append(("fleet/day_cost", 0.0,
                 f"host_hours={s['cost_host_hours']:.1f};"
                 f"slo_met={slo_ok};deadlines_met={jobs_ok};"
                 f"resizes={s['n_resize_decisions']}"))
    return rows
