"""Routed-fleet serving benches: prefix-affinity router over N replicas.

Drives the same prefix-heavy mixed trace through (a) one ``ServeEngine``
serving everything and (b) a ``Router`` over two same-seed replicas, and
reports wall time + tokens/s for each.  The trace is the regime the router
is built for: most requests share a long document head, so the hash-chain
prefix probe concentrates them on the replica that already holds the
head's pages while cold requests fill the other replica.

Correctness is asserted inside the bench, every pass: the routed fleet's
per-request token streams must be bit-identical to the single engine's
(dense-arch decode is slot/batch-composition independent — see
serve/engine.py), and the affinity-hit rate must be strictly positive on
this trace.  ``serve/router_*`` rows therefore bench the fast path of an
exact method, like the spec-decode rows.

Rows:

* ``serve/router_single_*``: wall to drain the trace on one engine.
* ``serve/router_fleet2_*``: wall for the 2-replica routed fleet, with
  affinity-hit rate, spill count, and the per-replica dispatch split in
  the derived column.
"""

from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

Row = Tuple[str, float, str]

ARCH = "qwen3-14b"
# num_pages oversized so prefix-cache registrations never evict mid-pass
GEOM = dict(smoke=True, max_batch=2, page_size=8, max_seq=96, seed=0,
            num_pages=1024)
HEAD_PAGES = 3
N_REQUESTS = 8
GEN = 6
SPILL_SLACK = 512
WARM_SEED = 11
MEASURED_SEEDS = (5, 9)


def _trace_specs(seed: int, vocab: int, page_size: int):
    """Prefix-heavy mix: even requests extend a shared document head,
    odd requests are cold random prompts; arrivals in pairs."""
    rng = np.random.RandomState(seed)
    head = rng.randint(0, vocab, HEAD_PAGES * page_size).astype(np.int32)
    specs = []
    for i in range(N_REQUESTS):
        if i % 2 == 0:
            tail = rng.randint(0, vocab, 3).astype(np.int32)
            prompt = np.concatenate([head, tail])
        else:
            prompt = rng.randint(0, vocab, 9).astype(np.int32)
        specs.append((prompt, GEN, (i // 2) * 2))
    return specs


def _drain_single(eng, specs):
    reqs = [eng.submit(p, g, arrival_step=eng.step_count + a)
            for p, g, a in specs]
    t0 = time.perf_counter()
    eng.run()
    wall = time.perf_counter() - t0
    gens = [r.generated for r in reqs]
    return wall, sum(len(g) for g in gens), gens


def _drain_routed(router, specs):
    """One pass on a reused (warm) fleet: arrivals are made relative to the
    router's current step and pass stats are computed from the events this
    pass appended (the router's own stats() is cumulative)."""
    ev0 = len(router.events("router"))
    at = router.step_count
    reqs = [router.submit(p, g, arrival_step=at + a) for p, g, a in specs]
    t0 = time.perf_counter()
    router.run()
    wall = time.perf_counter() - t0
    evs = router.events("router")[ev0:]
    hits = sum(1 for e in evs if e.matched_pages > 0)
    routable = sum(1 for e in evs if e.prompt_pages > 0)
    per_replica = [0] * len(router.engines)
    for e in evs:
        per_replica[e.replica] += 1
    stats = {
        "affinity_hit_rate": hits / routable if routable else 0.0,
        "spills": sum(1 for e in evs if e.reason == "spill"),
        "dispatch_per_replica": per_replica,
    }
    gens = [r.generated for r in reqs]
    return wall, sum(len(g) for g in gens), gens, stats


def bench_router() -> List[Row]:
    from repro.serve import Router, ServeEngine

    vocab = ServeEngine.config_for(ARCH, True).vocab_size
    single = ServeEngine(ARCH, **GEOM)
    # one fleet reused across passes so jit compiles stay in the warm-up;
    # each pass's document head is seed-distinct, so stale pages from the
    # previous pass never match and dispatch stays per-pass deterministic
    router = Router([ServeEngine(ARCH, **GEOM) for _ in range(2)],
                    spill_slack=SPILL_SLACK)

    walls_s, walls_f, toks = [], [], 0
    hit_rates, spills, splits = [], [], []
    for i, seed in enumerate((WARM_SEED,) + MEASURED_SEEDS):
        specs = _trace_specs(seed, vocab, GEOM["page_size"])
        wall_s, tok_s, gens_s = _drain_single(single, specs)
        wall_f, tok_f, gens_f, stats = _drain_routed(router, specs)
        assert gens_s == gens_f, "routed fleet diverged from single engine"
        assert tok_s == tok_f
        assert stats["affinity_hit_rate"] > 0, \
            "prefix-heavy trace produced no affinity hits"
        if i > 0:  # pass 0 only warms the jit caches
            walls_s.append(wall_s)
            walls_f.append(wall_f)
            toks += tok_s
            hit_rates.append(stats["affinity_hit_rate"])
            spills.append(stats["spills"])
            splits.append(stats["dispatch_per_replica"])
    wall_s, wall_f = sum(walls_s), sum(walls_f)
    split = [sum(s[j] for s in splits) for j in range(2)]
    sig = f"{ARCH}_r{N_REQUESTS}"
    return [
        (f"serve/router_single_{sig}", wall_s * 1e6,
         f"tok_per_s={toks / wall_s:.0f};requests={N_REQUESTS}"),
        (f"serve/router_fleet2_{sig}", wall_f * 1e6,
         f"tok_per_s={toks / wall_f:.0f};"
         f"affinity_hit_rate={np.mean(hit_rates):.2f};"
         f"spills={sum(spills)};"
         f"dispatch_split={split[0]}:{split[1]};bit_identical=yes"),
    ]
