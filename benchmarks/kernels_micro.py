"""Kernel microbenchmarks (CPU wall-clock of the jnp paths + interpret-mode
correctness deltas; the Pallas kernels target TPU, so us_per_call here is a
CPU proxy, not a TPU number)."""
from __future__ import annotations

import functools
from typing import List, Tuple

import jax
import jax.numpy as jnp

# the tuner's timer: one warmup invocation, then the timed mean.  (The old
# local _time called fn(*args) twice during warmup — the isinstance ternary
# evaluated it once per branch check — inflating warmup cost and, for
# stateful/donating callables, skewing the first timed call.)
from repro.kernels.tune.sweep import time_fn as _time

Row = Tuple[str, float, str]


def bench_kernels() -> List[Row]:
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    from repro.kernels.ssm_scan.ops import selective_scan
    from repro.kernels.ssm_scan.ref import selective_scan_ref
    from repro.kernels.sdca.ops import local_sdca

    rows: List[Row] = []
    ks = jax.random.split(jax.random.PRNGKey(0), 8)

    # flash attention: blocked vs naive at a seq where naive still fits
    b, h, s, d = 1, 8, 1024, 64
    q = jax.random.normal(ks[0], (b, h, s, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, h, s, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, h, s, d), jnp.float32)
    flash = jax.jit(lambda q, k, v: flash_attention(q, k, v, causal=True,
                                                    block_q=256, block_k=256))
    naive = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
    t_flash = _time(flash, q, k, v)
    t_naive = _time(naive, q, k, v)
    err = float(jnp.abs(flash(q, k, v) - naive(q, k, v)).max())
    rows.append(("kernels/flash_attention_1k", t_flash,
                 f"naive_us={t_naive:.0f};max_err={err:.1e}"))

    # selective scan: chunked vs step-by-step reference
    bt, sl, dn, n = 2, 512, 64, 16
    x = jax.random.normal(ks[3], (bt, sl, dn))
    dt = jax.nn.softplus(jax.random.normal(ks[4], (bt, sl, dn)))
    A = -jnp.abs(jax.random.normal(ks[5], (dn, n))) - 0.1
    B = jax.random.normal(ks[6], (bt, sl, n))
    C = jax.random.normal(ks[7], (bt, sl, n))
    D = jnp.full((dn,), 0.4)
    chunked = jax.jit(lambda *a: selective_scan(*a, chunk=128)[0])
    seq = jax.jit(lambda *a: selective_scan_ref(*a)[0])
    t_chunk = _time(chunked, x, dt, A, B, C, D)
    t_seq = _time(seq, x, dt, A, B, C, D)
    err = float(jnp.abs(chunked(x, dt, A, B, C, D)
                        - seq(x, dt, A, B, C, D)).max())
    rows.append(("kernels/ssm_scan_512", t_chunk,
                 f"sequential_us={t_seq:.0f};max_err={err:.1e}"))

    # SDCA inner loop (vmap path; pallas validated in tests)
    m, nl, dd, hh = 8, 512, 128, 512
    X = jax.random.normal(ks[0], (m, nl, dd))
    yv = jnp.sign(jax.random.normal(ks[1], (m, nl)))
    a0 = jnp.zeros((m, nl))
    w0 = jnp.zeros((dd,))
    idx = jnp.stack([jax.random.permutation(kk, nl)
                     for kk in jax.random.split(ks[2], m)])
    sdca = jax.jit(lambda X, y, a, w, i: local_sdca(
        X, y, a, w, i, 1.0, 1e-3, float(m * nl)))
    t_sdca = _time(sdca, X, yv, a0, w0, idx)
    rows.append(("kernels/sdca_8x512", t_sdca,
                 f"updates_per_s={m * nl / (t_sdca / 1e6):.0f}"))
    return rows


def bench_paged_decode() -> List[Row]:
    """Paged-native decode vs the legacy gather path at serving scale, plus
    the autotuner rows that picked the native blocking.

    Cache capacity is 2048 positions (B=4); fills are the tuner's ragged
    serving profile (longest sequence at half capacity).  The gather path
    pays the O(B*Hk*S*d) page gather plus O(capacity) attention every
    step; the paged-native stream path reads pages in place and stops at
    the longest live sequence.  Both run the same blocked online softmax,
    so outputs are bit-identical (max_err in the derived column is exact
    0).  us_per_call here is a CPU proxy; on TPU `impl="pallas"` runs the
    Pallas kernel from the same dispatcher.
    """
    import numpy as np

    from repro.kernels.flash_decode.ops import paged_decode_attention
    from repro.kernels.tune import ConfigCache, bench_rows, ensure

    b, hk, g, d, page = 4, 4, 2, 64, 16
    npp = 2048 // page
    shape = {"b": b, "hk": hk, "g": g, "d": d, "page": page, "npp": npp}
    cache = ConfigCache(path=None)  # in-memory: the bench is self-contained
    cfg = ensure("flash_decode_paged", shape, jnp.float32, cache=cache)
    ppp = cfg["pages_per_program"]

    from repro.kernels.tune import ragged_lengths

    n_pages = b * npp + 1
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(b, hk * g, d), jnp.float32)
    kp = jnp.asarray(rng.randn(n_pages, hk, page, d), jnp.float32)
    vp = jnp.asarray(rng.randn(n_pages, hk, page, d), jnp.float32)
    pt = jnp.asarray(np.stack([
        rng.choice(n_pages - 1, npp, replace=False) + 1 for _ in range(b)
    ]), jnp.int32)
    lens = jnp.asarray(ragged_lengths(b, npp * page))

    def run(impl):
        return jax.jit(functools.partial(
            paged_decode_attention, impl=impl, pages_per_program=ppp))

    native, gather = run("stream"), run("gather")
    t_native = _time(native, q, kp, vp, lens, pt)
    t_gather = _time(gather, q, kp, vp, lens, pt)
    err = float(jnp.abs(native(q, kp, vp, lens, pt)
                        - gather(q, kp, vp, lens, pt)).max())
    sig = f"b{b}_s{npp * page}"
    rows: List[Row] = [
        (f"serve/decode_paged_native_{sig}", t_native,
         f"ppp={ppp};speedup_vs_gather={t_gather / t_native:.2f}x;"
         f"max_err={err:.1e}"),
        (f"serve/decode_paged_gather_{sig}", t_gather,
         f"ppp={ppp};copies=O(B*Hk*S*d)"),
    ]
    rows.extend(bench_rows(cache))
    return rows
