"""Kernel microbenchmarks (CPU wall-clock of the jnp paths + interpret-mode
correctness deltas; the Pallas kernels target TPU, so us_per_call here is a
CPU proxy, not a TPU number)."""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

Row = Tuple[str, float, str]


def _time(fn, *args, iters=5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def bench_kernels() -> List[Row]:
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    from repro.kernels.ssm_scan.ops import selective_scan
    from repro.kernels.ssm_scan.ref import selective_scan_ref
    from repro.kernels.sdca.ops import local_sdca

    rows: List[Row] = []
    ks = jax.random.split(jax.random.PRNGKey(0), 8)

    # flash attention: blocked vs naive at a seq where naive still fits
    b, h, s, d = 1, 8, 1024, 64
    q = jax.random.normal(ks[0], (b, h, s, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, h, s, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, h, s, d), jnp.float32)
    flash = jax.jit(lambda q, k, v: flash_attention(q, k, v, causal=True,
                                                    block_q=256, block_k=256))
    naive = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
    t_flash = _time(flash, q, k, v)
    t_naive = _time(naive, q, k, v)
    err = float(jnp.abs(flash(q, k, v) - naive(q, k, v)).max())
    rows.append(("kernels/flash_attention_1k", t_flash,
                 f"naive_us={t_naive:.0f};max_err={err:.1e}"))

    # selective scan: chunked vs step-by-step reference
    bt, sl, dn, n = 2, 512, 64, 16
    x = jax.random.normal(ks[3], (bt, sl, dn))
    dt = jax.nn.softplus(jax.random.normal(ks[4], (bt, sl, dn)))
    A = -jnp.abs(jax.random.normal(ks[5], (dn, n))) - 0.1
    B = jax.random.normal(ks[6], (bt, sl, n))
    C = jax.random.normal(ks[7], (bt, sl, n))
    D = jnp.full((dn,), 0.4)
    chunked = jax.jit(lambda *a: selective_scan(*a, chunk=128)[0])
    seq = jax.jit(lambda *a: selective_scan_ref(*a)[0])
    t_chunk = _time(chunked, x, dt, A, B, C, D)
    t_seq = _time(seq, x, dt, A, B, C, D)
    err = float(jnp.abs(chunked(x, dt, A, B, C, D)
                        - seq(x, dt, A, B, C, D)).max())
    rows.append(("kernels/ssm_scan_512", t_chunk,
                 f"sequential_us={t_seq:.0f};max_err={err:.1e}"))

    # SDCA inner loop (vmap path; pallas validated in tests)
    m, nl, dd, hh = 8, 512, 128, 512
    X = jax.random.normal(ks[0], (m, nl, dd))
    yv = jnp.sign(jax.random.normal(ks[1], (m, nl)))
    a0 = jnp.zeros((m, nl))
    w0 = jnp.zeros((dd,))
    idx = jnp.stack([jax.random.permutation(kk, nl)
                     for kk in jax.random.split(ks[2], m)])
    sdca = jax.jit(lambda X, y, a, w, i: local_sdca(
        X, y, a, w, i, 1.0, 1e-3, float(m * nl)))
    t_sdca = _time(sdca, X, yv, a0, w0, idx)
    rows.append(("kernels/sdca_8x512", t_sdca,
                 f"updates_per_s={m * nl / (t_sdca / 1e6):.0f}"))
    return rows
