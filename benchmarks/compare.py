"""Compare two ``benchmarks.run --json`` payloads and gate perf regressions.

Usage:
  python -m benchmarks.compare BASELINE.json CURRENT.json [--max-ratio 2.5]
      [--min-us 1000]
  python -m benchmarks.compare . CURRENT.json        # newest BENCH_*.json

When BASELINE is a directory it resolves to the newest ``BENCH_*.json``
inside it: highest trailing PR number first (``BENCH_pr4.json`` beats
``BENCH_baseline_pr1.json``), modification time as the tie-break.  This is
how CI tracks the bench trajectory — each PR that records a snapshot
automatically becomes the next PR's baseline.

Exit-code contract (consumed by the CI ``perf-smoke`` job):
  0  no comparable row regressed beyond ``--max-ratio``
  1  at least one comparable row regressed (ratio > max-ratio), or a
     comparable category produced an ``/ERROR`` row in CURRENT that the
     baseline did not have
  2  invocation/environment problem: missing file, unreadable JSON, or the
     two payloads share no comparable rows

Which rows are compared ("pure-python" rows): CI runners have noisy clocks
and no accelerator, so only rows whose cost is dominated by Python/numpy/JAX
CPU work are gated —

* rows under ``kernels/`` and ``tune/`` (Pallas interpret-mode / CPU-proxy
  kernel microbenches) and ``roofline/`` (dry-run artifact summaries,
  absent in CI) are excluded;
* rows with a baseline ``us_per_call`` below ``--min-us`` are excluded: the
  harness reuses that column for derived non-time metrics (counts, ids) and
  sub-millisecond timings are below the shared-runner noise floor;
* rows present in only one payload are reported but never ratio-gated —
  except rows under ``serve/``: those are the engine-level serving benches
  (paged decode, chunked prefill, speculative decode), and one vanishing
  from CURRENT means a serving fast path silently stopped being measured,
  which fails the comparison like a regression.

The baseline was measured on a different machine than the CI runner; the
generous 2.5x default absorbs machine-speed variance, so this gate catches
order-of-magnitude algorithmic regressions, not single-digit-percent drift.
"""

from __future__ import annotations

import argparse
import json
import os
import re
import sys

EXCLUDED_PREFIXES = ("kernels/", "roofline/", "tune/")
# baseline rows under these prefixes must still exist in CURRENT
REQUIRED_PREFIXES = ("serve/",)


def newest_baseline(directory: str) -> str:
    """Newest BENCH_*.json in ``directory``: max PR number, then mtime."""
    candidates = []
    for name in os.listdir(directory):
        if not (name.startswith("BENCH_") and name.endswith(".json")):
            continue
        path = os.path.join(directory, name)
        m = re.search(r"(\d+)\.json$", name)
        pr = int(m.group(1)) if m else -1
        candidates.append((pr, os.path.getmtime(path), path))
    if not candidates:
        raise FileNotFoundError(f"no BENCH_*.json under {directory!r}")
    return max(candidates)[2]


def load_rows(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    return {r["name"]: float(r["us_per_call"]) for r in payload["rows"]}


def comparable(name: str, baseline_us: float, min_us: float) -> bool:
    if name.startswith(EXCLUDED_PREFIXES):
        return False
    return baseline_us >= min_us


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument(
        "--max-ratio",
        type=float,
        default=2.5,
        help="fail when current/baseline exceeds this (default 2.5)",
    )
    ap.add_argument(
        "--min-us",
        type=float,
        default=1000.0,
        help="ignore rows whose baseline is below this many us",
    )
    args = ap.parse_args(argv)

    try:
        baseline_path = (
            newest_baseline(args.baseline)
            if os.path.isdir(args.baseline)
            else args.baseline
        )
        if baseline_path != args.baseline:
            print(f"compare: baseline resolved to {baseline_path}")
        base = load_rows(baseline_path)
        cur = load_rows(args.current)
    except (OSError, ValueError, KeyError) as e:
        print(f"compare: cannot load payloads: {e}", file=sys.stderr)
        return 2

    regressions = []
    errors = []
    missing = []
    compared = 0
    for name, base_us in sorted(base.items()):
        if name.startswith(REQUIRED_PREFIXES) and name not in cur:
            print(f"  [MISSING] {name}: required row absent from current")
            missing.append(name)
            continue
        if not comparable(name, base_us, args.min_us):
            continue
        if name not in cur:
            # a vanished row usually means its producer errored; the /ERROR
            # sweep below turns that into a failure
            print(f"  [skip] {name}: missing from current")
            continue
        compared += 1
        ratio = cur[name] / base_us
        marker = "REGRESSION" if ratio > args.max_ratio else "ok"
        print(
            f"  [{marker}] {name}: {base_us:.0f} -> {cur[name]:.0f} us "
            f"({ratio:.2f}x)"
        )
        if ratio > args.max_ratio:
            regressions.append((name, ratio))
    for name in sorted(cur):
        if name.endswith("/ERROR") and not name.startswith(EXCLUDED_PREFIXES):
            if name not in base and name not in errors:
                errors.append(name)

    if compared == 0:
        print("compare: no comparable rows between payloads", file=sys.stderr)
        return 2
    if missing:
        print(f"compare: required rows missing from current: {missing}",
              file=sys.stderr)
        return 1
    if errors:
        print(f"compare: ERROR rows in current: {errors}", file=sys.stderr)
        return 1
    if regressions:
        print(
            f"compare: {len(regressions)} row(s) regressed beyond "
            f"{args.max_ratio}x: {regressions}",
            file=sys.stderr,
        )
        return 1
    print(f"compare: {compared} rows within {args.max_ratio}x of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
