"""Engine-level serving benches: chunked prefill + speculative decode.

Measures the whole ``ServeEngine`` step loop (not a kernel in isolation) on
a bursty mixed trace modeled on multi-turn / retrieval serving:

* a long "document" request arrives first and is registered in the prefix
  cache (its full prompt is the cross-request draft source);
* follow-up requests extend prefixes of that document — greedy decode
  makes their continuations literal copies of the document tail, so the
  n-gram/prefix-cache proposer drafts them at a high accept rate (the
  regime prompt-lookup decoding is built for);
* cold long random prompts arrive in the same bursts and keep monolithic
  prefill stalls in the loop.

Reported rows:

* ``serve/prefill_*``: wall time to drain the trace with monolithic vs
  chunked prefill.  The derived column carries wall-clock
  join-to-first-token p50/p99 (queueing included) and per-step stall
  p99/max — the head-of-line time a long prompt steals from every running
  decode, which is the quantity chunking bounds.
* ``serve/spec_decode_*``: end-to-end committed tokens/s without and with
  speculation, plus the measured accept rate and speedup.

Each engine runs a warm-up trace first (same lengths and arrival pattern,
different tokens) so jit compiles — every distinct chunk offset ``s0`` is
its own compile — stay out of measurement, then three measured passes on
distinct documents whose walls are pooled to damp shared-runner noise.  Generated tokens are asserted identical between the optimized and
baseline engines on every pass: these rows bench the fast path of an
exact method.
"""

from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

Row = Tuple[str, float, str]

ARCH = "qwen3-14b"
# num_pages is oversized so the four passes' prefix-cache registrations
# never trigger LRU eviction mid-measurement
GEOM = dict(smoke=True, max_batch=4, page_size=8, max_seq=256, seed=0,
            num_pages=1024)
DOC_SEED_LEN = 16
DOC_GEN = 160
FOLLOWUP_STARTS = (41, 57, 65, 73, 89)
FOLLOWUP_GEN = 80
COLD_PROMPTS = 2
COLD_LEN = 48
COLD_GEN = 12
PREFILL_CHUNK = 32
SPECULATE = 8
WARM_DOC_SEED = 11
MEASURED_DOC_SEEDS = (5, 7, 9)


def _document(eng, doc_seed: int) -> np.ndarray:
    """Seed + its greedy continuation: any prefix of the result continues,
    under greedy decode, along the result itself."""
    rng = np.random.RandomState(doc_seed)
    seed = rng.randint(0, eng.cfg.vocab_size, DOC_SEED_LEN).astype(np.int32)
    req = eng.submit(seed, DOC_GEN)
    eng.run()
    return np.concatenate([seed, np.asarray(req.generated, np.int32)])


def _trace(eng, doc: np.ndarray, seed: int):
    """Document + follow-ups + cold prompts, arrivals in bursts of four."""
    rng = np.random.RandomState(seed)
    at = eng.step_count  # arrivals relative to now: engines are reused
    reqs = [eng.submit(doc, 4, arrival_step=at)]
    for i, j in enumerate(FOLLOWUP_STARTS):
        gen = min(FOLLOWUP_GEN, len(doc) - j)
        reqs.append(eng.submit(doc[:j].copy(), gen,
                               arrival_step=at + ((i + 1) // 4) * 4))
    for i in range(COLD_PROMPTS):
        prompt = rng.randint(0, eng.cfg.vocab_size, COLD_LEN).astype(np.int32)
        reqs.append(eng.submit(prompt, COLD_GEN,
                               arrival_step=at + ((i + 6) // 4) * 4))
    return reqs


def _drain(eng, doc: np.ndarray, seed: int):
    """Submit the trace and drive the step loop with wall timestamps.

    Returns (wall_s, committed_tokens, join_ms, stall_ms, generations)."""
    reqs = _trace(eng, doc, seed)
    step0 = eng.step_count
    walls = [0.0]
    t0 = time.perf_counter()
    while not eng.scheduler.drained:
        eng.step()
        walls.append(time.perf_counter() - t0)
    tok = sum(len(r.generated) for r in reqs)
    joins = []
    for r in reqs:
        arrived = walls[max(r.arrival_step - step0, 0)]
        first = walls[r.first_token_step - step0 + 1]
        joins.append((first - arrived) * 1e3)
    stalls = np.diff(walls) * 1e3
    return walls[-1], tok, np.asarray(joins), stalls, [r.generated
                                                       for r in reqs]


def bench_serve_engine() -> List[Row]:
    from repro.serve import ServeEngine

    base = ServeEngine(ARCH, **GEOM)
    fast = ServeEngine(ARCH, prefill_chunk=PREFILL_CHUNK,
                       speculate=SPECULATE, **GEOM)

    runs_b, runs_f = [], []
    for i, doc_seed in enumerate((WARM_DOC_SEED,) + MEASURED_DOC_SEEDS):
        doc_b = _document(base, doc_seed)
        doc_f = _document(fast, doc_seed)
        assert np.array_equal(doc_b, doc_f)
        res_b = _drain(base, doc_b, seed=doc_seed)
        res_f = _drain(fast, doc_f, seed=doc_seed)
        assert res_b[4] == res_f[4], "optimized engine diverged from baseline"
        if i > 0:  # pass 0 only warms the jit caches
            runs_b.append(res_b)
            runs_f.append(res_f)

    def agg(runs):
        """Pool the measured passes: (wall_s, tok/s, joins, stalls)."""
        wall = sum(r[0] for r in runs)
        tok = sum(r[1] for r in runs)
        joins = np.concatenate([r[2] for r in runs])
        stalls = np.concatenate([r[3] for r in runs])
        return wall, tok / wall, joins, stalls

    wall_b, tps_b, joins_b, stalls_b = agg(runs_b)
    wall_f, tps_f, joins_f, stalls_f = agg(runs_f)
    stats_f = fast.stats()
    sig = f"{ARCH}_r{1 + len(FOLLOWUP_STARTS) + COLD_PROMPTS}"
    return [
        (f"serve/prefill_monolithic_{sig}", wall_b * 1e6,
         f"tok_per_s={tps_b:.0f};"
         f"join_p50_ms={np.percentile(joins_b, 50):.2f};"
         f"join_p99_ms={np.percentile(joins_b, 99):.2f};"
         f"stall_p99_ms={np.percentile(stalls_b, 99):.2f};"
         f"stall_max_ms={stalls_b.max():.2f}"),
        (f"serve/prefill_chunked_{sig}", wall_f * 1e6,
         f"chunk={PREFILL_CHUNK};tok_per_s={tps_f:.0f};"
         f"join_p50_ms={np.percentile(joins_f, 50):.2f};"
         f"join_p99_ms={np.percentile(joins_f, 99):.2f};"
         f"stall_p99_ms={np.percentile(stalls_f, 99):.2f};"
         f"stall_max_ms={stalls_f.max():.2f}"),
        (f"serve/spec_decode_off_{sig}", 1e6 / tps_b,
         f"tok_per_s={tps_b:.0f}"),
        (f"serve/spec_decode_on_{sig}", 1e6 / tps_f,
         f"k={SPECULATE};tok_per_s={tps_f:.0f};"
         f"accept_rate={stats_f.get('spec_accept_rate', 0.0):.2f};"
         f"speedup_vs_baseline={tps_f / tps_b:.2f}x;bit_identical=yes"),
    ]
