"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Figures 1/3/4/5/6 reproduce the
paper's CoCoA/CoCoA+ experiments on the synthetic MNIST stand-in; ernest/
planner rows exercise the §3 models end-to-end; kernels/* are the Pallas-
path microbenches; roofline/* summarizes the multi-pod dry-run artifacts.

  PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small problem / fewer m values (CI mode)")
    ap.add_argument("--skip-figures", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON (perf-trajectory baseline, "
                         "e.g. BENCH_baseline.json)")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    rows = []

    if not args.skip_figures:
        from benchmarks.context import get_context
        from benchmarks import figures
        ctx = get_context(quick=args.quick)
        for fn in (figures.fig1a_time_per_iter,
                   figures.fig1b_convergence_vs_m,
                   figures.fig1c_algorithms,
                   figures.fig3_model_fit,
                   figures.fig4_loo_m,
                   figures.fig5_forward_iters,
                   figures.fig6_forward_time,
                   figures.ernest_accuracy,
                   figures.planner_e2e,
                   figures.budget_query):
            t0 = time.time()
            try:
                rows.extend(fn(ctx))
            except Exception as e:  # noqa: BLE001
                rows.append((f"{fn.__name__}/ERROR", 0.0,
                             f"{type(e).__name__}:{e}"))
                traceback.print_exc(file=sys.stderr)
            print(f"# {fn.__name__} done in {time.time() - t0:.0f}s",
                  file=sys.stderr, flush=True)

    from benchmarks.kernels_micro import bench_kernels, bench_paged_decode
    try:
        rows.extend(bench_kernels())
    except Exception as e:  # noqa: BLE001
        rows.append(("kernels/ERROR", 0.0, f"{type(e).__name__}:{e}"))

    # paged-native vs gather decode + the autotuner rows that blocked it
    try:
        rows.extend(bench_paged_decode())
    except Exception as e:  # noqa: BLE001
        rows.append(("serve/decode_paged/ERROR", 0.0,
                     f"{type(e).__name__}:{e}"))

    # engine-level chunked prefill + speculative decode on a bursty trace
    try:
        from benchmarks.serve_engine import bench_serve_engine

        rows.extend(bench_serve_engine())
    except Exception as e:  # noqa: BLE001
        rows.append(("serve/engine/ERROR", 0.0, f"{type(e).__name__}:{e}"))

    # prefix-affinity router over a 2-replica fleet vs one engine
    try:
        from benchmarks.router import bench_router

        rows.extend(bench_router())
    except Exception as e:  # noqa: BLE001
        rows.append(("serve/router/ERROR", 0.0, f"{type(e).__name__}:{e}"))

    try:
        from benchmarks.fleet import bench_fleet

        rows.extend(bench_fleet())
    except Exception as e:  # noqa: BLE001
        rows.append(("fleet/ERROR", 0.0, f"{type(e).__name__}:{e}"))

    # roofline summary from dry-run artifacts (if the sweep has been run)
    try:
        from benchmarks.roofline import load_results, roofline_fraction
        res = load_results()
        for r in res:
            rows.append((f"roofline/{r['arch']}/{r['shape']}",
                         max(r["t_compute_s"], r["t_memory_s"],
                             r["t_collective_s"]) * 1e6,
                         f"dom={r['dominant']};frac={roofline_fraction(r):.4f}"))
    except Exception as e:  # noqa: BLE001
        rows.append(("roofline/ERROR", 0.0, f"{type(e).__name__}:{e}"))

    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    if args.json:
        payload = {
            "quick": args.quick,
            "python": platform.python_version(),
            "rows": [{"name": n, "us_per_call": us, "derived": str(d)}
                     for n, us, d in rows],
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
